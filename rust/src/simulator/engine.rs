//! The discrete-event engine: instances, migrations, and the event loop —
//! sharded by instance group and run on parallel worker threads with a
//! deterministic cross-shard merge.
//!
//! # Sharding contract (the `--shards` / [`SimConfig::shards`] knob)
//!
//! Simulated time is cut into windows `[kΔ, (k+1)Δ)` where Δ =
//! [`SimConfig::effective_window`] (conservative lookahead derived from
//! the cost model's minimum link latency). Each shard owns a contiguous
//! instance range ([`crate::simulator::shard_of`]) — its event heap,
//! `Queues`, `Scratch`, and `PagedCache` state — and runs one window at a
//! time touching **only** its own instances plus a frozen read-only view
//! of the cluster (`Ctx`). Every cross-instance effect — migration
//! retargets, EP/PD transfer landings releasing the source, cache-fetch
//! sourcing, directory publish/retract gossip, controller ticks, arrival
//! routing — is a boundary message delivered at the window barrier in
//! canonical `(t, instance, seq)` order.
//!
//! The non-negotiable invariant: **the barrier protocol runs at every
//! shard count, including 1**, so `shards = N` is bit-identical to
//! `shards = 1` — [`SimResult::digest`] never moves with the shard count.
//! Δ is a *fidelity* knob (how stale the routing view may be), not a
//! correctness knob. The golden-determinism suite sweeps `shards ∈
//! {1, 2, 4}` over every pinned shape × policy as the safety net for the
//! parallelization itself.
//!
//! # Hot-path invariants (the `bench_sim_hotpath` contract)
//!
//! * **Hash once.** A request's content-hash chains ([`HashChains`]) are
//!   derived exactly once, when it is routed, and shared via `Arc` —
//!   routing, commits, migration targeting, and fetch planning all borrow
//!   the same chains (they move shard-to-shard with the request). Never
//!   call `content::spec_*_hashes` from event handlers; go through
//!   `chains_entry`.
//! * **Reuse scratch.** Candidate lists, affinity scores, and directory
//!   prefix sweeps write into per-run scratch buffers (`Scratch` per
//!   shard, `RouteScratch` at the barrier). The steady-state worker loop
//!   allocates nothing per event; boundary messages reuse the `Vec`s the
//!   cache layer already returns (`commit_hashes`, `drain_evicted`).
//! * **Index, don't scan.** Queue membership questions go through the
//!   `Queues` id → slot index and per-stage FIFOs; hot maps use the
//!   in-crate Fx hasher (`util::fxhash`), which also makes iteration
//!   order — and therefore seeded runs — deterministic across processes.
//!
//! [`SimResult::digest`] fingerprints a run's observable behaviour; the
//! golden-determinism suite pins digests for seeded traces so refactors
//! of this file can prove themselves behaviour-preserving.

use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier, Mutex, RwLock};

use crate::cache::{
    BlockHash, CacheStats, ContentDirectory, HashChains, PagedCache, COST_IMAGE,
};
use crate::config::ControllerConfig;
use crate::controller::{
    ClusterSample, DrainTracker, InstanceSample, ReconfigEvent, ReconfigPolicy,
    StageLoadEstimator, StageRates,
};
use crate::core::{Lifecycle, Phase, RequestId, RequestSpec, Stage};
use crate::faults::FaultKind;
use crate::costmodel::{
    encode_cost, exec_time, iteration_cost, parallel_time, prefill_resume_cost, sequential_time,
    Cost,
};
use crate::metrics::RunMetrics;
use crate::obs::trace::{mask_bits, SpanKind, Tracer};
use crate::router::{RoutePolicy, Router};
use crate::scheduler::{
    compute_image_budget, compute_token_budget, Batch, BudgetProfile, Budgets, Queues, ReqState,
    Scheduler, StageMask, TaskWork,
};
use crate::simulator::{
    cache_blocks, img_blocks_for, kv_blocks_for, shard_bounds, shard_of, SimConfig, IMG_BLOCK,
    KV_BLOCK,
};
use crate::util::fxhash::FxHashMap;

// ---------------------------------------------------------------- events

/// Shard-local events. Every event belongs to exactly one instance (and
/// therefore one shard); anything cross-instance travels as a [`Msg`]
/// instead and re-enters a heap only at a window barrier.
#[derive(Debug)]
enum EvKind {
    /// `requests[i]` was routed to this instance at the barrier and
    /// arrives here at its arrival time.
    Deliver(usize),
    /// The instance's current batch completes.
    BatchDone,
    /// An admitted migration pull lands (the target holds the data).
    TransferLand { req: RequestId },
    /// A standalone cache fetch (fetch-over-recompute) landed: the
    /// request parked in `SimInstance::fetching` resumes with the fetched
    /// content credited, or falls back to recompute when the advertised
    /// holder's advertisement went stale mid-flight.
    FetchDone { req: RequestId },
    /// The migrated request's transfer landed at the target; this source
    /// instance releases its queue slot and cache blocks.
    SrcRelease { req: RequestId },
    /// Barrier-injected nudge: admit pending pulls / start a batch.
    Wake,
    /// A request salvaged from a crashed instance re-enters here (fault
    /// plan only): attach cache hits on this instance — resuming at the
    /// longest locally cached prefix — consider a fetch-over-recompute
    /// from surviving holders, then dispatch into the queues exactly like
    /// a fresh delivery.
    Redeliver(Box<ReqState>),
}

#[derive(Debug)]
struct Ev {
    t: f64,
    seq: u64,
    /// Global id of the instance this event belongs to.
    inst: u32,
    /// Instance incarnation this event was scheduled against. A crash
    /// bumps the instance's epoch (recovery does not), so events minted
    /// before the crash — its in-flight `BatchDone`, parked `FetchDone`s —
    /// are dropped by the pop-time guard instead of acting on the reborn
    /// instance. Not part of the heap order: `(t, seq)` stays the key.
    epoch: u32,
    kind: EvKind,
}

// Heap ordering only needs (t, seq) — `seq` is unique within a shard, so
// equality on the key pair is a genuine equivalence and `EvKind` needs no
// `PartialEq` (nor `Clone`: events are moved, never copied).
impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reverse comparison
        other
            .t
            .total_cmp(&self.t)
            .then(other.seq.cmp(&self.seq))
    }
}

// ------------------------------------------------------------- messages

/// Cross-shard boundary message payloads. Emitted by shard workers
/// mid-window, applied by the barrier in canonical order.
#[derive(Debug)]
enum MsgKind {
    /// The creator committed these KV block hashes: advertise them.
    PublishKv(Vec<BlockHash>),
    /// The creator committed these image-embedding block hashes.
    PublishImg(Vec<BlockHash>),
    /// The creator evicted these KV blocks: withdraw the advertisements.
    RetractKv(Vec<BlockHash>),
    /// The creator evicted these image-embedding blocks.
    RetractImg(Vec<BlockHash>),
    /// The creator wants `req` migrated to an instance serving `next`
    /// (§4.3 step 1); the barrier routes it over the live cluster view.
    MigrateReq { req: RequestId, next: Stage },
    /// The creator (pull target) admitted `req` from `src` and scheduled
    /// its transfer to land at `land`: the barrier tells `src` to release
    /// the request's queue slot and cache blocks.
    SrcRelease { src: usize, req: RequestId, land: f64 },
}

/// A boundary message. Barrier delivery order is `(t, inst, seq)` —
/// time-sorted, creator-id tie-broken, per-creator creation order last —
/// which is independent of how instances are partitioned into shards:
/// the root of the shards=N ≡ shards=1 guarantee.
#[derive(Debug)]
struct Msg {
    t: f64,
    /// Global id of the creating instance.
    inst: u32,
    /// Per-shard monotone creation counter.
    seq: u64,
    kind: MsgKind,
}

fn emit_into(outbox: &mut Vec<Msg>, msg_seq: &mut u64, t: f64, inst: u32, kind: MsgKind) {
    *msg_seq += 1;
    outbox.push(Msg { t, inst, seq: *msg_seq, kind });
}

// -------------------------------------------------------------- instances

/// A migration waiting for the target to pull it (paper §4.3 step 1).
/// Transfer bytes are decided at *admit* time, when the target knows how
/// much of the payload its content-addressed cache already holds (delta
/// transfer — a block the target caches never crosses the link).
#[derive(Debug, Clone)]
struct PendingPull {
    req: ReqState,
    src: usize,
    phase: Phase, // EpMigration or PdMigration
    /// Payload size in content tokens (image tokens for EP, prefill
    /// tokens for PD) before any target-side cache credit.
    payload_tokens: usize,
    /// KV tokens the target already held when it admitted the pull.
    kv_cached: usize,
    created: f64,
}

/// A fetch-over-recompute transfer in flight: the routed target lacked
/// content a peer's cache holds, and the cost model priced pulling it
/// below recomputing (encode for image blocks, prefill for KV prefixes).
/// Unlike a migration pull, the request never leaves the target — it is
/// parked here until the transfer lands, blocks already reserved.
#[derive(Debug, Clone)]
struct PendingFetch {
    req: ReqState,
    /// Peer shipping the image-embedding blocks, if that part was priced
    /// worth fetching.
    img_src: Option<usize>,
    /// Peer shipping the KV prefix, and the prefix length (tokens, block
    /// aligned) the fetch extends the local cached prefix to.
    kv_src: Option<(usize, usize)>,
    /// The plan was already re-validated once after a stale landing
    /// (holder's advertisement withdrawn mid-flight) and redirected to a
    /// surviving holder. One redirect per fetch: a second stale landing
    /// falls back to recompute instead of chasing a churning directory.
    redirected: bool,
    /// This fetch already contributed to `stale_fetches` (an abandoned
    /// part on an earlier landing); a later landing must not count it
    /// again — `stale_fetches` stays at most one per fetch, mirroring
    /// `fetches`.
    stale_counted: bool,
}

/// The cluster-wide content directory pair (KV + image planes). Owned by
/// the frozen window context: shard workers read it (`_ro` sweeps), only
/// the barrier mutates it (publish/retract gossip applied in canonical
/// message order), so every shard count sees the same directory history.
struct DirPair {
    kv: ContentDirectory,
    img: ContentDirectory,
}

struct SimInstance {
    id: usize,
    mask: StageMask,
    /// Incarnation counter: bumped by a fault-plan crash so stale heap
    /// events (stamped with the old epoch at push) are discarded. Stays 0
    /// for the whole run when no fault plan is active.
    epoch: u32,
    sched: Box<dyn Scheduler>,
    queues: Queues,
    kv: PagedCache,
    img: PagedCache,
    /// Batch currently executing (None = idle) + its start time.
    current: Option<(Batch, f64)>,
    /// Inbound migrations not yet admitted (queue = backpressure).
    inbox: Vec<PendingPull>,
    /// Admitted pulls whose transfer is in flight.
    incoming: FxHashMap<u64, PendingPull>,
    /// Requests parked while a cache fetch is in flight (directory mode).
    fetching: FxHashMap<u64, PendingFetch>,
}

impl SimInstance {
    fn load(&self) -> f64 {
        self.queues.total() as f64
            + self.inbox.len() as f64
            + self.incoming.len() as f64
            + self.fetching.len() as f64
            + self.kv.utilization() * 4.0
            + self.img.utilization()
    }

    /// Blocks this request needs on an instance with our mask (delegates
    /// to the mask-level formula `reserve_blocks` also uses — admission
    /// and reservation must never drift apart).
    fn kv_tokens_needed(&self, r: &ReqState) -> usize {
        kv_tokens_needed_mask(self.mask, r)
    }

    fn img_blocks_needed(&self, r: &ReqState) -> usize {
        img_blocks_needed_mask(self.mask, r)
    }

    /// Admission check. Blocks the request already pinned (a cached
    /// prefix acquired at attach) cost nothing; evictable cached blocks
    /// count as reclaimable — only genuine pressure backpressures.
    fn can_admit(&self, r: &ReqState) -> bool {
        let kv_need = kv_blocks_for(self.kv_tokens_needed(r))
            .saturating_sub(self.kv.held_blocks(r.spec.id));
        let img_need = self
            .img_blocks_needed(r)
            .saturating_sub(self.img.held_blocks(r.spec.id));
        kv_need <= self.kv.available_blocks() && img_need <= self.img.available_blocks()
    }

    /// Pin whatever the content-addressed caches already hold for a newly
    /// routed request, and derive its pipeline progress from the hits: a
    /// cached embedding skips encode, a cached KV prefix starts prefill
    /// mid-prompt (always leaving >= 1 token so prefill emits the first
    /// output token). Must run before the scheduler first sees `r`.
    fn attach(
        &mut self,
        r: &mut ReqState,
        kv_hashes: &[BlockHash],
        img_hashes: &[BlockHash],
        report: &mut CacheReport,
    ) {
        let id = r.spec.id;
        let img_need = self.img_blocks_needed(r);
        if img_need > 0 && !self.img.has_request(id) {
            // cap in *occupied blocks*, not raw image tokens: an image
            // smaller than IMG_BLOCK (e.g. qwen2-vl's 380 tokens) still
            // occupies — and is cached as — one whole block
            let cached = self
                .img
                .acquire_prefix(id, img_hashes, img_need * IMG_BLOCK)
                .expect("fresh request");
            let per = r.spec.tokens_per_image.max(1);
            let imgs = (cached / per).min(r.spec.num_images);
            r.cached_images = imgs;
            r.encoded_images = r.encoded_images.max(imgs);
            report.img_hit_images += imgs;
            report.img_total_images += r.spec.num_images;
        }
        if self.kv_tokens_needed(r) > 0 && !self.kv.has_request(id) {
            let cap = r.spec.prefill_tokens().saturating_sub(1);
            let cached = self
                .kv
                .acquire_prefix(id, kv_hashes, cap)
                .expect("fresh request");
            r.cached_prefill = cached;
            r.prefilled = r.prefilled.max(cached);
            report.kv_hit_tokens += cached;
            report.kv_lookup_tokens += cap;
        }
    }

    fn release_all(&mut self, id: RequestId) {
        if self.kv.has_request(id) {
            self.kv.free(id).unwrap();
        }
        if self.img.has_request(id) {
            self.img.free(id).unwrap();
        }
    }
}

// ----------------------------------------------------------------- engine

/// Cross-request reuse accounting for one simulation run.
#[derive(Debug, Default, Clone, Copy)]
pub struct CacheReport {
    /// Prefill tokens served from cached KV prefixes at attach.
    pub kv_hit_tokens: usize,
    /// Prefill tokens that were eligible for prefix reuse (sum of
    /// per-request prefill length minus the always-recomputed last token).
    pub kv_lookup_tokens: usize,
    /// Images whose embeddings were cache hits (encode skipped).
    pub img_hit_images: usize,
    pub img_total_images: usize,
    /// Migration payload tokens never transferred (target already held
    /// them — delta transfer).
    pub migration_tokens_saved: usize,
    /// Aggregated per-instance KV-cache counters.
    pub kv_stats: CacheStats,
    /// Aggregated per-instance image-cache counters.
    pub img_stats: CacheStats,
    /// Cluster-wide content-directory counters (zero when disabled).
    pub directory: DirectoryReport,
}

/// Content-directory accounting for one simulation run: how often the
/// cluster-wide view was consulted, kept current, and converted into
/// fetch-over-recompute transfers.
#[derive(Debug, Default, Clone, Copy)]
pub struct DirectoryReport {
    /// Prefix/holder sweeps answered (routing + fetch decisions).
    pub queries: u64,
    /// (hash, holder) advertisements published.
    pub publishes: u64,
    /// (hash, holder) advertisements withdrawn (evictions, role flips).
    pub retractions: u64,
    /// Cache fetches taken instead of recomputing.
    pub fetches: usize,
    /// Image embeddings served by peer fetch (encode skipped).
    pub fetched_images: usize,
    /// KV prefix tokens served by peer fetch (prefill shortened).
    pub fetched_kv_tokens: usize,
    /// Fetch landings that abandoned at least one part because the
    /// advertised holder evicted the content AND no surviving holder
    /// remained (or the fetch was already redirected once) — the request
    /// fell back to recomputing that part (staleness).
    pub stale_fetches: usize,
    /// Stale landings rescued by re-validating the plan against the
    /// *current* directory and redirecting to a surviving holder — each
    /// of these would have been a `stale_fetches` recompute before the
    /// landing-time re-validation existed.
    pub redirected_fetches: usize,
}

impl DirectoryReport {
    fn absorb(&mut self, o: &DirectoryReport) {
        self.queries += o.queries;
        self.publishes += o.publishes;
        self.retractions += o.retractions;
        self.fetches += o.fetches;
        self.fetched_images += o.fetched_images;
        self.fetched_kv_tokens += o.fetched_kv_tokens;
        self.stale_fetches += o.stale_fetches;
        self.redirected_fetches += o.redirected_fetches;
    }
}

impl CacheReport {
    /// Fraction of reuse-eligible prefill tokens served from cache.
    pub fn kv_hit_rate(&self) -> f64 {
        if self.kv_lookup_tokens == 0 {
            0.0
        } else {
            self.kv_hit_tokens as f64 / self.kv_lookup_tokens as f64
        }
    }
    /// Fraction of images whose encode was skipped.
    pub fn img_hit_rate(&self) -> f64 {
        if self.img_total_images == 0 {
            0.0
        } else {
            self.img_hit_images as f64 / self.img_total_images as f64
        }
    }

    fn absorb(&mut self, o: &CacheReport) {
        self.kv_hit_tokens += o.kv_hit_tokens;
        self.kv_lookup_tokens += o.kv_lookup_tokens;
        self.img_hit_images += o.img_hit_images;
        self.img_total_images += o.img_total_images;
        self.migration_tokens_saved += o.migration_tokens_saved;
        self.kv_stats.merge(&o.kv_stats);
        self.img_stats.merge(&o.img_stats);
        self.directory.absorb(&o.directory);
    }
}

/// Simulation output: metrics + counters for sanity checks and reports.
#[derive(Debug)]
pub struct SimResult {
    pub metrics: RunMetrics,
    pub migrations: usize,
    pub batches: usize,
    /// Discrete events processed by the loop (the `bench_sim_hotpath`
    /// throughput denominator: events/sec measures engine speed
    /// independently of how much simulated time a trace covers).
    pub events: u64,
    /// Requests still unfinished at the horizon.
    pub unfinished: usize,
    /// Requests no instance could serve, dropped at arrival (they create
    /// no lifecycle and are excluded from latency metrics — this counter
    /// is their only trace).
    pub dropped_requests: usize,
    /// Completed online role flips (0 when the controller is off).
    pub reconfigs: usize,
    /// Flip history: when, which instance, from which role to which.
    pub reconfig_events: Vec<ReconfigEvent>,
    /// Content-addressed cache reuse accounting.
    pub cache: CacheReport,
    /// Flight-recorder spans (empty unless `SimConfig::trace`); export
    /// with [`SimResult::trace_json`]. Excluded from [`SimResult::digest`]
    /// — observation must never look like a behaviour change.
    pub trace: Vec<crate::obs::trace::Span>,
    /// Spans overwritten in the rings (0 = the whole run fit).
    pub trace_dropped: u64,
    /// Fault-plan events actually applied (0 with an empty plan — and the
    /// fault counters below are then excluded from [`SimResult::digest`],
    /// so pinned no-fault digests never move).
    pub fault_events: usize,
    /// Instance crashes applied from the fault plan.
    pub crashes: usize,
    /// Requests salvaged off a crashed instance and successfully re-routed
    /// to a surviving instance (including parked requests retried after a
    /// recovery).
    pub recovered_requests: usize,
    /// Salvaged requests that never found a surviving instance for their
    /// stage: parked forever (retries on) or abandoned outright (retries
    /// off). Their lifecycles merge into the metrics as unfinished.
    pub lost_requests: usize,
}

impl SimResult {
    /// Order-independent fingerprint of a run's observable behaviour:
    /// every lifecycle (phase times, token timestamps, completion) folded
    /// in ascending request-id order, plus the run counters. Two runs are
    /// behaviourally identical iff their digests match — the golden
    /// determinism suite pins these for seeded traces, and perf refactors
    /// of the engine must keep them bit-identical. Since the sharded
    /// engine landed, the suite also sweeps `shards ∈ {1, 2, 4}` — the
    /// digest must not move with the shard count either.
    ///
    /// `events` is deliberately excluded: it fingerprints the *engine's
    /// internal step count*, not request-visible behaviour.
    pub fn digest(&self) -> u64 {
        use crate::cache::content::mix;
        let mut ids: Vec<u64> = self.metrics.lifecycles.keys().copied().collect();
        ids.sort_unstable();
        let mut h = mix(0x5eed, ids.len() as u64);
        for id in ids {
            let lc = &self.metrics.lifecycles[&id];
            h = mix(h, id);
            h = mix(h, lc.arrival.to_bits());
            for p in &lc.phase_time {
                h = mix(h, p.to_bits());
            }
            h = mix(h, lc.first_token_at.map_or(1, |t| t.to_bits()));
            h = mix(h, lc.finished_at.map_or(2, |t| t.to_bits()));
            h = mix(h, lc.token_times.len() as u64);
            for t in &lc.token_times {
                h = mix(h, t.to_bits());
            }
        }
        for v in [
            self.migrations as u64,
            self.batches as u64,
            self.unfinished as u64,
            self.dropped_requests as u64,
            self.reconfigs as u64,
            self.cache.kv_hit_tokens as u64,
            self.cache.kv_lookup_tokens as u64,
            self.cache.img_hit_images as u64,
            self.cache.img_total_images as u64,
            self.cache.migration_tokens_saved as u64,
            self.cache.directory.fetches as u64,
            self.cache.directory.fetched_kv_tokens as u64,
            self.cache.directory.fetched_images as u64,
            self.cache.directory.stale_fetches as u64,
            self.cache.directory.redirected_fetches as u64,
        ] {
            h = mix(h, v);
        }
        // fault counters fold in only when the plan actually fired: an
        // empty (or never-due) plan must reproduce the pinned golden
        // digests bit-for-bit
        if self.fault_events > 0 {
            for v in [
                self.fault_events as u64,
                self.crashes as u64,
                self.recovered_requests as u64,
                self.lost_requests as u64,
            ] {
                h = mix(h, v);
            }
        }
        h
    }

    /// The recorded spans as Chrome trace-event JSON (Perfetto-loadable).
    pub fn trace_json(&self) -> crate::util::json::Json {
        crate::obs::trace::chrome_trace_json(&self.trace)
    }
}

// ------------------------------------------------------- shards & barrier

/// Per-shard scratch buffers reused across events — the worker loop's
/// guarantee of allocation-free batch application. Cleared by the
/// producer before use; contents never survive an event.
#[derive(Default)]
struct Scratch {
    /// Requests finishing in the batch being applied.
    to_finish: Vec<RequestId>,
    /// Requests migrating out of the batch being applied.
    to_migrate: Vec<(RequestId, Stage)>,
}

/// Barrier-side scratch for routing decisions (arrivals + migration
/// retargets all route at the barrier, over the frozen cluster view).
#[derive(Default)]
struct RouteScratch {
    /// Instance ids eligible for the current routing decision.
    candidates: Vec<usize>,
    /// Cache-affinity score per candidate (parallel to `candidates`).
    affinity: Vec<f64>,
    /// Drain-gated (then raw) loads per candidate.
    gated: Vec<f64>,
    /// Directory sweep output, KV plane (indexed by instance id).
    kv_pfx: Vec<usize>,
    /// Directory sweep output, image plane.
    img_pfx: Vec<usize>,
}

/// One shard: a contiguous instance range plus every piece of mutable
/// state its worker thread may touch mid-window. Nothing in here is
/// visible to other shards until the barrier drains `outbox`.
struct Shard {
    /// Global id of `instances[0]` (the shard covers `lo..lo + len`).
    lo: usize,
    instances: Vec<SimInstance>,
    heap: BinaryHeap<Ev>,
    /// Event sequence counter (unique within the shard; cross-shard
    /// ordering never compares raw event seqs — only message order).
    seq: u64,
    /// Boundary messages created this window, drained at the barrier.
    outbox: Vec<Msg>,
    /// Message sequence counter (per-creator creation order).
    msg_seq: u64,
    events: u64,
    batches: usize,
    report: CacheReport,
    /// Fetch-over-recompute counters banked shard-side; directory
    /// publish/retract/query totals come from the directory itself.
    dir_report: DirectoryReport,
    /// Lifecycles of requests currently owned by this shard (they move
    /// with the request on cross-shard migration; the barrier does the
    /// move, so workers always find their own requests here).
    lifecycles: FxHashMap<u64, Lifecycle>,
    /// When each in-flight request last became ready to be scheduled
    /// (arrival or migration landing) — feeds queue-phase accounting.
    ready_since: FxHashMap<u64, f64>,
    /// Hash-once memo: request id → its content-hash chains.
    chains: FxHashMap<u64, Arc<HashChains>>,
    /// Shared empty chain (content cache off ⇒ every request maps here).
    no_chains: Arc<HashChains>,
    content_cache: bool,
    /// Directory mode: publish/retract gossip must be emitted.
    dirs_on: bool,
    scratch: Scratch,
    tracer: Tracer,
}

impl Shard {
    /// Push a shard-local event (the only way events enter the heap
    /// mid-window; barrier-injected events use the same counter, at the
    /// barrier, so per-shard seq order is globally consistent).
    fn push(&mut self, t: f64, inst: u32, kind: EvKind) {
        self.seq += 1;
        let epoch = self.instances[inst as usize - self.lo].epoch;
        self.heap.push(Ev { t, seq: self.seq, inst, epoch, kind });
    }

    /// Emit a boundary message for barrier delivery.
    fn emit(&mut self, t: f64, inst: u32, kind: MsgKind) {
        emit_into(&mut self.outbox, &mut self.msg_seq, t, inst, kind);
    }
}

/// Barrier-owned state: everything that represents the *cluster* rather
/// than one shard — the router, drain tracker, controller, arrival
/// cursor, and global counters. Only the barrier phase (single-threaded,
/// between windows) touches this.
struct Control {
    router: Router,
    tracker: DrainTracker,
    controller: Option<(ControllerConfig, StageLoadEstimator, ReconfigPolicy)>,
    /// Next controller tick time (INFINITY once the controller goes
    /// quiescent or is absent).
    next_tick: f64,
    migrations: usize,
    dropped: usize,
    /// Barrier-side event count (controller ticks + drops); shard workers
    /// count their own.
    events: u64,
    report: CacheReport,
    tracer: Tracer,
    /// Cursor into `order` (arrival-sorted request indices).
    next_arrival: usize,
    /// Request indices sorted by (arrival, index) — generator traces are
    /// already sorted, but routing order must not depend on that.
    order: Vec<u32>,
    /// instance gid → shard index (stable for the whole run: role flips
    /// never move an instance across shards — see tests/shard_partition.rs).
    inst_shard: Vec<usize>,
    /// Barrier message merge buffer (reused every window).
    msgs: Vec<Msg>,
    no_chains: Arc<HashChains>,
    content_cache: bool,
    /// Load routed to each instance this barrier but not yet visible in
    /// its queues (arrivals all land within the window, so this clears
    /// every barrier via `touched`).
    pending: Vec<f64>,
    touched: Vec<usize>,
    rs: RouteScratch,
    /// Fault-plan machinery (None with an empty plan: the engine then
    /// behaves exactly as if the fault subsystem did not exist).
    faults: Option<FaultState>,
}

/// Barrier-owned fault-plan state: the sorted schedule cursor, per-
/// instance liveness, and the salvage/park accounting. Like everything
/// else in [`Control`], only the single-threaded barrier phase touches it
/// — fault application is cluster-global work, so digests stay
/// partition-free with faults on.
struct FaultState {
    /// The plan in canonical order ([`FaultPlan::sorted_events`]).
    events: Vec<crate::faults::FaultEvent>,
    /// Cursor: `events[..idx]` have been applied.
    idx: usize,
    /// Park salvaged requests with no live candidate and retry them on
    /// the next recovery, instead of abandoning them immediately.
    retry: bool,
    /// Which instances are currently crashed.
    failed: Vec<bool>,
    /// The role each crashed instance held at crash time (restored — with
    /// fresh, empty caches — on recovery).
    saved_masks: Vec<StageMask>,
    /// Salvaged requests waiting for an instance serving their stage to
    /// come back (retry mode only).
    parked: Vec<Salvage>,
    /// Lifecycles of abandoned requests (retry off), merged into the
    /// metrics as unfinished at end of run.
    dead: Vec<(u64, Lifecycle)>,
    lost: usize,
    recovered: usize,
    crashes: usize,
    applied: usize,
}

/// One request rescued off a crashed instance, with the per-request
/// ownership that travels with it (its lifecycle and memoized chains).
struct Salvage {
    req: ReqState,
    lc: Lifecycle,
    ch: Option<Arc<HashChains>>,
}

/// Frozen per-window fault factors shard workers read (the mutable twin
/// lives in [`FaultState`]-driven barrier updates): per-instance batch
/// slowdown and the cluster-wide link degradation multiplier. `None` with
/// an empty plan — the duration-scaling branches then cost nothing.
struct FaultView {
    /// Batch-duration multiplier per instance (1.0 = healthy).
    slow: Vec<f64>,
    /// Transfer/fetch-duration multiplier (1.0 = healthy).
    link: f64,
}

/// The frozen read-only cluster view shard workers see mid-window:
/// window end, per-instance loads as of the barrier, and the content
/// directory (barrier-mutated only, so its history is partition-free).
struct Ctx {
    /// Window end: workers process events strictly before `t1`.
    t1: f64,
    horizon: f64,
    /// Per-instance load snapshot (directory mode only — fetch sourcing
    /// breaks holder ties by load; empty otherwise).
    loads: Vec<f64>,
    dirs: Option<DirPair>,
    /// Straggler / link-degradation factors (fault plan only; barrier-
    /// mutated, so every shard count scales the same durations).
    faults: Option<FaultView>,
}

/// Borrow an instance by global id across the shard slice.
fn inst_ref<'a>(shards: &'a [Shard], inst_shard: &[usize], gid: usize) -> &'a SimInstance {
    let s = inst_shard[gid];
    &shards[s].instances[gid - shards[s].lo]
}

/// Hash-once chain lookup: derive on first touch, share the `Arc` after.
// invlint: derive-once
fn chains_entry(
    chains: &mut FxHashMap<u64, Arc<HashChains>>,
    content_cache: bool,
    no_chains: &Arc<HashChains>,
    spec: &RequestSpec,
) -> Arc<HashChains> {
    if !content_cache {
        return no_chains.clone();
    }
    chains
        .entry(spec.id.0)
        .or_insert_with(|| Arc::new(HashChains::of_spec(spec, KV_BLOCK, IMG_BLOCK)))
        .clone()
}

/// Emit retraction gossip for blocks the instance's caches just evicted.
/// Must be called after every operation that can evict (reserve/grow);
/// with the directory off the eviction log is not even tracked.
fn emit_retractions(
    inst: &mut SimInstance,
    dirs_on: bool,
    outbox: &mut Vec<Msg>,
    msg_seq: &mut u64,
    now: f64,
) {
    if !dirs_on {
        return;
    }
    let gid = inst.id as u32;
    let kv = inst.kv.drain_evicted();
    if !kv.is_empty() {
        emit_into(outbox, msg_seq, now, gid, MsgKind::RetractKv(kv));
    }
    let img = inst.img.drain_evicted();
    if !img.is_empty() {
        emit_into(outbox, msg_seq, now, gid, MsgKind::RetractImg(img));
    }
}

/// Reserve blocks for an admitted request (must follow `can_admit`).
/// Returns (KV tokens, image tokens) already present locally — the
/// delta-transfer credit for migrated-in requests. Free function over the
/// split-borrowed cache fields so callers can iterate `queues.running()`
/// without cloning each request.
fn reserve_blocks(
    mask: StageMask,
    kv: &mut PagedCache,
    img: &mut PagedCache,
    r: &ReqState,
    ch: &HashChains,
) -> (usize, usize) {
    let id = r.spec.id;
    let mut kv_cached = 0;
    let mut img_cached = 0;
    let kv_tokens = kv_tokens_needed_mask(mask, r);
    if kv_tokens > 0 {
        if !kv.has_request(id) {
            kv_cached = kv
                .acquire_prefix(id, &ch.kv, r.spec.prefill_tokens().saturating_sub(1))
                .expect("fresh table");
        }
        kv.grow(id, kv_tokens).expect("can_admit checked kv capacity");
    }
    let img_need = img_blocks_needed_mask(mask, r);
    if img_need > 0 {
        if !img.has_request(id) {
            // occupied-block cap (sub-block images round up, see attach)
            img_cached = img
                .acquire_prefix(id, &ch.img, img_need * IMG_BLOCK)
                .expect("fresh table")
                .min(r.spec.image_tokens());
        }
        img.grow(id, img_need * IMG_BLOCK).expect("can_admit checked image capacity");
    }
    (kv_cached, img_cached)
}

/// Build the per-instance state for a cluster layout (shared by
/// [`simulate`] and the engine's unit tests, which drive event handlers
/// directly against the same instances the production loop uses).
fn build_instances(cfg: &SimConfig, masks: &[StageMask], track_evictions: bool) -> Vec<SimInstance> {
    masks
        .iter()
        .enumerate()
        .map(|(id, &mask)| {
            let (kv_blocks, img_blocks) = cache_blocks(&cfg.model, &cfg.device, mask);
            let mut kv = PagedCache::new(kv_blocks, KV_BLOCK, 1024);
            let mut img =
                PagedCache::new(img_blocks, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
            if track_evictions {
                kv.set_eviction_tracking(true);
                img.set_eviction_tracking(true);
            }
            SimInstance {
                id,
                mask,
                epoch: 0,
                sched: cfg.policy.make(mask),
                queues: Queues::default(),
                kv,
                img,
                current: None,
                inbox: Vec::new(),
                incoming: FxHashMap::default(),
                fetching: FxHashMap::default(),
            }
        })
        .collect()
}

/// Partition built instances into shards (contiguous ranges matching
/// [`shard_bounds`]); all other shard state starts empty.
fn build_shards(cfg: &SimConfig, instances: Vec<SimInstance>, n_shards: usize) -> Vec<Shard> {
    let n = instances.len();
    let dirs_on = cfg.content_cache && cfg.cache_directory;
    let no_chains = Arc::new(HashChains::empty());
    let mut it = instances.into_iter();
    shard_bounds(n, n_shards)
        .into_iter()
        .map(|(lo, hi)| Shard {
            lo,
            instances: (&mut it).take(hi - lo).collect(),
            heap: BinaryHeap::new(),
            seq: 0,
            outbox: Vec::new(),
            msg_seq: 0,
            events: 0,
            batches: 0,
            report: CacheReport::default(),
            dir_report: DirectoryReport::default(),
            lifecycles: FxHashMap::default(),
            ready_since: FxHashMap::default(),
            chains: FxHashMap::default(),
            no_chains: no_chains.clone(),
            content_cache: cfg.content_cache,
            dirs_on,
            scratch: Scratch::default(),
            tracer: if cfg.trace {
                Tracer::with_capacity(cfg.trace_capacity)
            } else {
                Tracer::off()
            },
        })
        .collect()
}

/// Run the simulation over a request trace.
///
/// Dispatches on [`SimConfig::shards`]: one shard runs the windowed loop
/// inline on the calling thread; more shards run it on scoped worker
/// threads synchronized per window. Both paths execute the *same*
/// barrier protocol, so the digest is independent of the choice.
pub fn simulate(cfg: &SimConfig, requests: &[RequestSpec]) -> SimResult {
    let masks = cfg.cluster.instance_masks();
    let n = masks.len();
    let n_shards = cfg.shards.clamp(1, n.max(1));
    let profile = BudgetProfile::default();
    let token_budget = compute_token_budget(&cfg.model, &cfg.device, &profile, cfg.slo.tpot).max(64);
    let image_budget = compute_image_budget(&cfg.model, &cfg.device, &profile, cfg.slo.tpot).max(1);
    let budgets = Budgets { token_budget, image_budget, max_decode_batch: 512 };

    // cluster-wide content directory (fetch-over-recompute) — requires the
    // content cache; off reproduces per-instance affinity bit-for-bit
    let dirs = (cfg.content_cache && cfg.cache_directory).then(|| DirPair {
        kv: ContentDirectory::new(n),
        img: ContentDirectory::new(n),
    });

    let instances = build_instances(cfg, &masks, dirs.is_some());
    let mut shards = build_shards(cfg, instances, n_shards);

    // arrival routing order: by (arrival, index) — generator traces are
    // already sorted, but the barrier must not depend on that
    let mut order: Vec<u32> = (0..requests.len() as u32).collect();
    order.sort_by(|&a, &b| {
        requests[a as usize]
            .arrival
            .total_cmp(&requests[b as usize].arrival)
            .then(a.cmp(&b))
    });

    // elastic control plane (estimator -> policy -> drain tracker)
    let controller = cfg.controller.as_ref().map(|cc| {
        let rates = StageRates::from_model(&cfg.model, &cfg.device);
        (
            cc.clone(),
            StageLoadEstimator::new(cc.clone(), rates, Some(cfg.slo)),
            ReconfigPolicy::new(cc.clone()),
        )
    });
    let next_tick = controller.as_ref().map_or(f64::INFINITY, |(cc, _, _)| cc.tick);

    let mut ctl = Control {
        router: Router::new(RoutePolicy::LeastLoaded, cfg.seed),
        tracker: DrainTracker::new(n),
        controller,
        next_tick,
        migrations: 0,
        dropped: 0,
        events: 0,
        report: CacheReport::default(),
        tracer: if cfg.trace {
            Tracer::with_capacity(cfg.trace_capacity)
        } else {
            Tracer::off()
        },
        next_arrival: 0,
        order,
        inst_shard: (0..n).map(|i| shard_of(i, n, n_shards)).collect(),
        msgs: Vec::new(),
        no_chains: Arc::new(HashChains::empty()),
        content_cache: cfg.content_cache,
        pending: vec![0.0; n],
        touched: Vec::new(),
        rs: RouteScratch::default(),
        faults: (!cfg.faults.is_empty()).then(|| FaultState {
            events: cfg.faults.sorted_events(),
            idx: 0,
            retry: cfg.faults.retry,
            failed: vec![false; n],
            saved_masks: vec![StageMask::NONE; n],
            parked: Vec::new(),
            dead: Vec::new(),
            lost: 0,
            recovered: 0,
            crashes: 0,
            applied: 0,
        }),
    };

    let faults_view =
        (!cfg.faults.is_empty()).then(|| FaultView { slow: vec![1.0; n], link: 1.0 });
    let mut ctx =
        Ctx { t1: 0.0, horizon: cfg.horizon, loads: Vec::new(), dirs, faults: faults_view };

    // invlint: allow(no-shard1-fastpath) -- execution-strategy dispatch, not a
    // protocol fork: this arm drives the identical advance()/run_window() windowed
    // barrier loop inline that run_threaded() drives on scoped worker threads
    if n_shards == 1 {
        // serial path: same windowed protocol, no threads
        let mut w = 0.0f64;
        let mut next_k = 0u64;
        while advance(&mut shards, &mut ctl, &mut ctx, &mut w, &mut next_k, cfg, requests) {
            run_window(&mut shards[0], &ctx, cfg, &budgets, requests);
        }
    } else {
        run_threaded(&mut shards, &mut ctl, &mut ctx, cfg, &budgets, requests);
    }

    assemble_result(shards, ctl, ctx, requests)
}

/// The threaded drive loop: one scoped worker per shard, two barriers per
/// window (start/end), shard state handed back to the main thread at each
/// barrier so it can run the single-threaded barrier phase.
fn run_threaded(
    shards: &mut Vec<Shard>,
    ctl: &mut Control,
    ctx: &mut Ctx,
    cfg: &SimConfig,
    budgets: &Budgets,
    requests: &[RequestSpec],
) {
    let n_shards = shards.len();
    let slots: Vec<Mutex<Option<Shard>>> =
        shards.drain(..).map(|s| Mutex::new(Some(s))).collect();
    let ctx_lock = RwLock::new(std::mem::replace(
        ctx,
        Ctx { t1: 0.0, horizon: cfg.horizon, loads: Vec::new(), dirs: None, faults: None },
    ));
    let start = Barrier::new(n_shards + 1);
    let end = Barrier::new(n_shards + 1);
    let done = AtomicBool::new(false);

    std::thread::scope(|scope| {
        for sid in 0..n_shards {
            let slots = &slots;
            let ctx_lock = &ctx_lock;
            let start = &start;
            let end = &end;
            let done = &done;
            scope.spawn(move || loop {
                start.wait();
                if done.load(Ordering::Acquire) {
                    break;
                }
                {
                    let ctx = ctx_lock.read().unwrap();
                    let mut slot = slots[sid].lock().unwrap();
                    run_window(slot.as_mut().unwrap(), &ctx, cfg, budgets, requests);
                }
                end.wait();
            });
        }

        let mut w = 0.0f64;
        let mut next_k = 0u64;
        loop {
            // barrier phase: main thread holds every shard + the ctx
            let live = {
                let mut held: Vec<Option<Shard>> =
                    slots.iter().map(|m| m.lock().unwrap().take()).collect();
                let mut shards_now: Vec<Shard> =
                    held.iter_mut().map(|s| s.take().unwrap()).collect();
                let mut guard = ctx_lock.write().unwrap();
                let live = advance(
                    &mut shards_now, ctl, &mut guard, &mut w, &mut next_k, cfg, requests,
                );
                for (m, s) in slots.iter().zip(shards_now) {
                    *m.lock().unwrap() = Some(s);
                }
                live
            };
            if !live {
                done.store(true, Ordering::Release);
                start.wait();
                break;
            }
            start.wait(); // release workers into the window
            end.wait(); // wait for every shard to finish it
        }
    });

    *shards = slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().unwrap())
        .collect();
    *ctx = ctx_lock.into_inner().unwrap();
}

/// Merge shard + barrier state into the final [`SimResult`].
fn assemble_result(
    shards: Vec<Shard>,
    mut ctl: Control,
    ctx: Ctx,
    requests: &[RequestSpec],
) -> SimResult {
    let _ = requests;
    let fs = ctl.faults.take();
    let Control {
        tracker,
        migrations,
        dropped,
        events,
        mut report,
        mut tracer,
        ..
    } = ctl;
    let mut metrics = RunMetrics::default();
    let mut unfinished = 0;
    let (mut fault_events, mut crashes, mut recovered, mut lost) = (0usize, 0usize, 0usize, 0usize);
    if let Some(fs) = fs {
        fault_events = fs.applied;
        crashes = fs.crashes;
        recovered = fs.recovered;
        // still-parked requests never found a survivor: they are lost,
        // and their lifecycles merge as unfinished (same for requests
        // abandoned outright with retries off)
        lost = fs.lost + fs.parked.len();
        for s in fs.parked {
            unfinished += 1;
            metrics.insert(s.req.spec.id, s.lc);
        }
        for (id, lc) in fs.dead {
            unfinished += 1;
            metrics.insert(RequestId(id), lc);
        }
    }
    let mut total_events = events;
    let mut batches = 0;
    let mut dir_report = DirectoryReport::default();
    let mut spans = tracer.take_spans();
    let mut trace_dropped = tracer.dropped();
    for shard in shards {
        let Shard {
            instances,
            events,
            batches: b,
            report: srep,
            dir_report: sdir,
            lifecycles,
            tracer: mut stracer,
            ..
        } = shard;
        total_events += events;
        batches += b;
        report.absorb(&srep);
        dir_report.absorb(&sdir);
        for (id, lc) in lifecycles {
            if lc.finished_at.is_none() {
                unfinished += 1;
            }
            metrics.insert(RequestId(id), lc);
        }
        for inst in &instances {
            report.kv_stats.merge(&inst.kv.stats());
            report.img_stats.merge(&inst.img.stats());
        }
        // runtime twin the analyzer cannot see: every paged cache must end
        // the run structurally sound (no leaked refcounts, no double-held
        // blocks). Debug builds — so the golden determinism suite and every
        // `cargo test` run — sweep it at end-of-run for free.
        #[cfg(debug_assertions)]
        for inst in &instances {
            if let Err(e) = inst.kv.verify_integrity() {
                panic!("end-of-run KV cache integrity violated: {e}");
            }
            if let Err(e) = inst.img.verify_integrity() {
                panic!("end-of-run image cache integrity violated: {e}");
            }
        }
        trace_dropped += stracer.dropped();
        spans.append(&mut stracer.take_spans());
    }
    if let Some(d) = ctx.dirs {
        dir_report.queries += d.kv.stats().queries + d.img.stats().queries;
        dir_report.publishes += d.kv.stats().publishes + d.img.stats().publishes;
        dir_report.retractions += d.kv.stats().retractions + d.img.stats().retractions;
        report.directory = dir_report;
    }
    // canonical span order: merged across rings, independent of sharding
    spans.sort_by(|a, b| {
        a.start
            .total_cmp(&b.start)
            .then(a.instance.cmp(&b.instance))
            .then(a.request.cmp(&b.request))
            .then(a.end.total_cmp(&b.end))
            .then((a.kind as u8).cmp(&(b.kind as u8)))
            .then(a.detail.cmp(&b.detail))
    });
    SimResult {
        metrics,
        migrations,
        batches,
        events: total_events,
        unfinished,
        dropped_requests: dropped,
        reconfigs: tracker.num_reconfigs(),
        reconfig_events: tracker.events,
        cache: report,
        trace: spans,
        trace_dropped,
        fault_events,
        crashes,
        recovered_requests: recovered,
        lost_requests: lost,
    }
}

// ---------------------------------------------------------- barrier phase

/// One barrier: apply last window's boundary messages in canonical order,
/// run due controller ticks, pick the next window, route its arrivals,
/// and freeze the read-only context. Returns false when the run is over
/// (nothing left at or before the horizon).
// invlint: barrier-phase
fn advance(
    shards: &mut [Shard],
    ctl: &mut Control,
    ctx: &mut Ctx,
    w: &mut f64,
    next_k: &mut u64,
    cfg: &SimConfig,
    requests: &[RequestSpec],
) -> bool {
    barrier_phase(shards, ctl, &mut ctx.dirs, *w, cfg);
    // due fault events apply here — after the message drain (so salvage
    // sees a settled directory) and before controller ticks (so the
    // controller observes the post-crash cluster)
    apply_faults(shards, ctl, ctx, *w, cfg);
    while ctl.next_tick <= *w {
        controller_tick(shards, ctl, &mut ctx.dirs, *w, cfg, requests);
    }

    // earliest pending work anywhere: shard heaps, arrivals, next tick,
    // next scheduled fault
    let mut m = ctl.next_tick;
    for s in shards.iter() {
        if let Some(ev) = s.heap.peek() {
            m = m.min(ev.t);
        }
    }
    if ctl.next_arrival < ctl.order.len() {
        m = m.min(requests[ctl.order[ctl.next_arrival] as usize].arrival);
    }
    if let Some(fs) = ctl.faults.as_ref() {
        if fs.idx < fs.events.len() {
            m = m.min(fs.events[fs.idx].t);
        }
    }
    if !(m.is_finite() && m <= cfg.horizon) {
        return false;
    }

    // window index containing `m`. The `max(next_k)` guard absorbs FP
    // edge cases where `m` quantizes back into the window just finished:
    // at worst one empty window runs, never a skipped event.
    let dt = cfg.effective_window();
    let k = ((m / dt) as u64).max(*next_k);
    *next_k = k + 1;
    let t1 = (k + 1) as f64 * dt;

    route_arrivals(shards, ctl, &mut ctx.dirs, t1, cfg, requests);

    // freeze the window context workers will read
    ctx.t1 = t1;
    if ctx.dirs.is_some() {
        ctx.loads.clear();
        for gid in 0..ctl.inst_shard.len() {
            ctx.loads.push(inst_ref(shards, &ctl.inst_shard, gid).load());
        }
    }
    *w = t1;
    true
}

/// Drain every shard's outbox and apply the messages in canonical
/// `(t, creator, seq)` order — the single point where cross-shard effects
/// become visible, and the reason the partition cannot influence anything.
// invlint: hot-path
fn barrier_phase(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    w: f64,
    cfg: &SimConfig,
) {
    {
        let Control { pending, touched, .. } = &mut *ctl;
        for i in touched.drain(..) {
            pending[i] = 0.0;
        }
    }
    let mut msgs = std::mem::take(&mut ctl.msgs);
    msgs.clear();
    for s in shards.iter_mut() {
        msgs.append(&mut s.outbox);
    }
    msgs.sort_unstable_by(|a, b| {
        a.t.total_cmp(&b.t).then(a.inst.cmp(&b.inst)).then(a.seq.cmp(&b.seq))
    });
    // runtime twin of the sharding contract (invlint sees structure, not
    // order): the drain must walk strictly increasing (t, creator, seq) —
    // a duplicate key would mean two shards minted the same identity and
    // delivery order would silently depend on the partition
    #[cfg(debug_assertions)]
    let mut prev: Option<(f64, u32, u64)> = None;
    for msg in msgs.drain(..) {
        #[cfg(debug_assertions)]
        {
            if let Some((pt, pi, ps)) = prev {
                let ord = pt.total_cmp(&msg.t).then(pi.cmp(&msg.inst)).then(ps.cmp(&msg.seq));
                debug_assert!(
                    ord == std::cmp::Ordering::Less,
                    "barrier drain out of canonical order: ({pt}, {pi}, {ps}) then \
                     ({}, {}, {})",
                    msg.t,
                    msg.inst,
                    msg.seq
                );
            }
            prev = Some((msg.t, msg.inst, msg.seq));
        }
        let gid = msg.inst as usize;
        match msg.kind {
            MsgKind::PublishKv(h) => {
                if let Some(d) = dirs.as_mut() {
                    d.kv.publish(gid, &h);
                }
            }
            MsgKind::PublishImg(h) => {
                if let Some(d) = dirs.as_mut() {
                    d.img.publish(gid, &h);
                }
            }
            MsgKind::RetractKv(h) => {
                if let Some(d) = dirs.as_mut() {
                    d.kv.retract(gid, &h);
                }
            }
            MsgKind::RetractImg(h) => {
                if let Some(d) = dirs.as_mut() {
                    d.img.retract(gid, &h);
                }
            }
            MsgKind::MigrateReq { req, next } => {
                barrier_migrate(shards, ctl, dirs, gid, req, next, msg.t, w, cfg);
            }
            MsgKind::SrcRelease { src, req, land } => {
                let s = ctl.inst_shard[src];
                shards[s].push(land.max(w), src as u32, EvKind::SrcRelease { req });
            }
        }
    }
    ctl.msgs = msgs;
}

/// §4.3 step 1, barrier side: snapshot the request at its source, pick a
/// pull target for its next stage over the live (barrier-time) cluster
/// view, enqueue the offer in the target's inbox, and move the request's
/// per-shard ownership (lifecycle, ready time, chains) to the target's
/// shard. `created` is when the source asked (message time), so migration
/// phase accounting is unchanged by the deferred routing.
#[allow(clippy::too_many_arguments)]
fn barrier_migrate(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    src: usize,
    id: RequestId,
    next_stage: Stage,
    created: f64,
    w: f64,
    cfg: &SimConfig,
) {
    let _ = cfg;
    let ssrc = ctl.inst_shard[src];
    let lsrc = src - shards[ssrc].lo;
    let Some(r) = shards[ssrc].instances[lsrc].queues.find_running(id) else {
        return;
    };
    r.migrating = true;
    let snapshot = r.clone();
    let phase = match next_stage {
        Stage::Prefill => Phase::EpMigration,
        _ => Phase::PdMigration,
    };
    let payload_tokens = match next_stage {
        // EP migration carries the image-token embeddings
        Stage::Prefill => snapshot.spec.image_tokens(),
        // PD migration carries the prefix KV cache
        _ => snapshot.spec.prefill_tokens(),
    };
    {
        let Control { rs, inst_shard, .. } = &mut *ctl;
        rs.candidates.clear();
        for gid in 0..inst_shard.len() {
            if gid != src && inst_ref(shards, inst_shard, gid).mask.serves(next_stage) {
                rs.candidates.push(gid);
            }
        }
    }
    // cache affinity: a target already holding the payload's blocks needs
    // (almost) nothing transferred. The directory answers for every
    // candidate in one sweep; without it each private index is scanned.
    let ch = chains_entry(
        &mut shards[ssrc].chains,
        ctl.content_cache,
        &ctl.no_chains,
        &snapshot.spec,
    );
    build_affinity2(shards, ctl, dirs, &ch, next_stage == Stage::Prefill);
    match route_pick2(shards, ctl) {
        Some(dst) => {
            ctl.migrations += 1;
            let sdst = ctl.inst_shard[dst];
            if sdst != ssrc {
                // per-request ownership follows the request across shards
                if let Some(lc) = shards[ssrc].lifecycles.remove(&id.0) {
                    shards[sdst].lifecycles.insert(id.0, lc);
                }
                if let Some(t) = shards[ssrc].ready_since.remove(&id.0) {
                    shards[sdst].ready_since.insert(id.0, t);
                }
                if let Some(c) = shards[ssrc].chains.remove(&id.0) {
                    shards[sdst].chains.insert(id.0, c);
                }
            }
            let ldst = dst - shards[sdst].lo;
            shards[sdst].instances[ldst].inbox.push(PendingPull {
                req: snapshot,
                src,
                phase,
                payload_tokens,
                kv_cached: 0,
                created,
            });
            ctl.pending[dst] += 1.0;
            ctl.touched.push(dst);
            // the target may be idle: make sure it looks at its inbox
            shards[sdst].push(w, dst as u32, EvKind::Wake);
        }
        None => {
            // nowhere to go (incomplete cluster): request is stuck; it
            // will count as unfinished. Un-mark so we don't spin.
            if let Some(r) = shards[ssrc].instances[lsrc].queues.find_running(id) {
                r.migrating = false;
            }
        }
    }
}

/// Route every arrival landing in the upcoming window `[w, t1)`. Routed
/// requests get their lifecycle/chains planted in the owner shard and a
/// `Deliver` event at their arrival time; unservable ones are dropped
/// here (they never touch a shard).
fn route_arrivals(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    t1: f64,
    cfg: &SimConfig,
    requests: &[RequestSpec],
) {
    while ctl.next_arrival < ctl.order.len() {
        let idx = ctl.order[ctl.next_arrival] as usize;
        let spec = &requests[idx];
        let now = spec.arrival;
        if !(now < t1 && now <= cfg.horizon) {
            break;
        }
        ctl.next_arrival += 1;
        // route by request type (paper §4): first needed stage
        let first = spec.first_stage();
        {
            let Control { rs, inst_shard, .. } = &mut *ctl;
            rs.candidates.clear();
            for gid in 0..inst_shard.len() {
                if inst_ref(shards, inst_shard, gid).mask.serves(first) {
                    rs.candidates.push(gid);
                }
            }
        }
        // content identity is derived exactly once, here (the hash-once
        // rule); every later touchpoint borrows the shard's memoized Arc
        let ch = if ctl.content_cache {
            // invlint: allow(hash-once) -- THE sanctioned derivation: chains are
            // born at arrival routing and every later touchpoint shares this Arc
            Arc::new(HashChains::of_spec(spec, KV_BLOCK, IMG_BLOCK))
        } else {
            ctl.no_chains.clone()
        };
        build_affinity2(shards, ctl, dirs, &ch, true);
        let Some(target) = route_pick2(shards, ctl) else {
            // no instance can serve this request type: count the drop
            // explicitly; it leaves no state behind anywhere
            ctl.dropped += 1;
            ctl.events += 1;
            crate::log_trace!("t={now:.6} drop req={} (no instance serves {first:?})", spec.id.0);
            ctl.tracer.span(
                SpanKind::Drop,
                crate::obs::trace::NO_INSTANCE as usize,
                spec.id.0,
                now,
                now,
                0,
            );
            continue;
        };
        let rid = spec.id;
        crate::log_trace!("t={now:.6} arrival req={} -> inst{target}", rid.0);
        let s = ctl.inst_shard[target];
        shards[s].lifecycles.insert(rid.0, Lifecycle::new(spec.arrival));
        shards[s].ready_since.insert(rid.0, now);
        if ctl.content_cache {
            shards[s].chains.insert(rid.0, ch);
        }
        shards[s].push(now, target as u32, EvKind::Deliver(idx));
        ctl.pending[target] += 1.0;
        ctl.touched.push(target);
    }
}

/// Fill `rs.affinity` (parallel to `rs.candidates`) with each candidate's
/// cache-affinity score for the chains `ch`. `with_img` gates the image
/// plane (migration targeting for a PD hop only scores the KV plane,
/// matching the payload it would ship).
///
/// With the directory: one sweep per plane answers every candidate.
/// Directory off (content cache still on): per-candidate private-index
/// scans with a **pick-preserving early-exit**. Once some candidate holds
/// the full chain and is routable (not draining, load within
/// [`Router::affinity_load_cap`]), it wins `pick_affinity` outright —
/// maximum possible affinity, ties broken toward lower load — so the
/// only later candidates that could still displace it are routable ones
/// at *strictly lower* load (they might also hold the full chain). Only
/// those are scanned; everything else is skipped with affinity 0, which
/// cannot change the outcome because a full-affinity candidate is
/// already on the board.
///
/// Loads include `pending` — work routed at this barrier that the owner
/// shard has not delivered yet — so back-to-back routing decisions see
/// each other exactly like consecutive arrivals used to.
fn build_affinity2(
    shards: &[Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    ch: &HashChains,
    with_img: bool,
) {
    let Control { rs, tracker, inst_shard, pending, content_cache, .. } = &mut *ctl;
    rs.affinity.clear();
    let n = inst_shard.len();
    if let Some(d) = dirs.as_mut() {
        d.kv.prefix_blocks_into(&ch.kv, &mut rs.kv_pfx);
        if with_img {
            d.img.prefix_blocks_into(&ch.img, &mut rs.img_pfx);
        } else {
            rs.img_pfx.clear();
            rs.img_pfx.resize(n, 0);
        }
        for &c in &rs.candidates {
            rs.affinity
                .push((rs.kv_pfx[c] * KV_BLOCK + rs.img_pfx[c] * IMG_BLOCK) as f64);
        }
    } else if *content_cache {
        let full_img = if with_img { ch.img.len() * IMG_BLOCK } else { 0 };
        let full = (ch.kv.len() * KV_BLOCK + full_img) as f64;
        // the same eligibility rule pick_affinity applies, precomputed so
        // the early-exit can never hide a holder the pick would still need
        let mut min_load = f64::INFINITY;
        for &c in &rs.candidates {
            if !tracker.is_draining(c) {
                min_load = min_load.min(inst_ref(shards, inst_shard, c).load() + pending[c]);
            }
        }
        let cap = Router::affinity_load_cap(min_load);
        // load of the winning routable full holder found so far
        let mut winner_load: Option<f64> = None;
        for &c in &rs.candidates {
            let load = inst_ref(shards, inst_shard, c).load() + pending[c];
            let routable = !tracker.is_draining(c) && load <= cap;
            if let Some(wl) = winner_load {
                if !routable || load >= wl {
                    // cannot displace the current full holder: skip the
                    // scan (a zero here never changes the pick)
                    rs.affinity.push(0.0);
                    continue;
                }
            }
            let inst = inst_ref(shards, inst_shard, c);
            let mut a = inst.kv.lookup_prefix(&ch.kv) * KV_BLOCK;
            if with_img {
                a += inst.img.lookup_prefix(&ch.img) * IMG_BLOCK;
            }
            let a = a as f64;
            rs.affinity.push(a);
            if a >= full && full > 0.0 && routable {
                winner_load = Some(load);
            }
        }
    } else {
        rs.affinity.resize(rs.candidates.len(), 0.0);
    }
}

/// Route among `rs.candidates` (affinity scores already built by
/// [`build_affinity2`]), treating mid-drain instances as ineligible
/// (infinite load) and preferring cache affinity: a candidate holding
/// cached content wins over a merely idle one; zero affinity everywhere
/// degrades to the plain load policy. If *every* candidate is mid-drain,
/// fall back to their raw loads: work is never dropped just because
/// flips are in flight.
fn route_pick2(shards: &[Shard], ctl: &mut Control) -> Option<usize> {
    if ctl.rs.candidates.is_empty() {
        return None;
    }
    let Control { rs, tracker, inst_shard, pending, router, .. } = &mut *ctl;
    rs.gated.clear();
    for &i in &rs.candidates {
        rs.gated.push(if tracker.is_draining(i) {
            f64::INFINITY
        } else {
            inst_ref(shards, inst_shard, i).load() + pending[i]
        });
    }
    if let Some(p) = router.pick_affinity(&rs.gated, &rs.affinity) {
        return Some(rs.candidates[p]);
    }
    rs.gated.clear();
    for &i in &rs.candidates {
        rs.gated.push(inst_ref(shards, inst_shard, i).load() + pending[i]);
    }
    router.pick(&rs.gated).map(|p| rs.candidates[p])
}

/// Re-offer running requests whose next stage their host no longer serves
/// and that own no in-flight migration — a role flip (or an earlier
/// failed hand-off) can orphan them, and nothing else retries.
fn retry_stranded(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    now: f64,
    w: f64,
    cfg: &SimConfig,
) {
    for gid in 0..ctl.inst_shard.len() {
        let s = ctl.inst_shard[gid];
        let li = gid - shards[s].lo;
        let mask = shards[s].instances[li].mask;
        let stranded: Vec<(RequestId, Stage)> = shards[s].instances[li]
            .queues
            .running()
            .iter()
            .filter(|r| !r.migrating && !mask.serves(r.stage()))
            .map(|r| (r.spec.id, r.stage()))
            .collect();
        for (id, stage) in stranded {
            barrier_migrate(shards, ctl, dirs, gid, id, stage, now, w, cfg);
        }
    }
}

/// One controller-tick observation: per-instance backlogs by next stage
/// (queues + in-flight pulls) plus the windowed latency tails, gathered
/// in global instance order across shards. Crashed instances sample as
/// unavailable (same as draining): their capacity vanishes from the
/// estimate, which is what lets the controller see the hole.
fn cluster_sample_sharded(
    shards: &[Shard],
    inst_shard: &[usize],
    tracker: &DrainTracker,
    failed: Option<&[bool]>,
    now: f64,
    w: &crate::metrics::WindowStats,
) -> ClusterSample {
    let mut out = ClusterSample {
        t: now,
        instances: Vec::with_capacity(inst_shard.len()),
        ttft_p90: w.ttft_p90(),
        tpot_p90: w.tpot_p90(),
    };
    for gid in 0..inst_shard.len() {
        let inst = inst_ref(shards, inst_shard, gid);
        let down = tracker.is_draining(inst.id) || failed.is_some_and(|f| f[gid]);
        let mut s = InstanceSample::idle(inst.mask, down);
        s.batch_items = inst.current.as_ref().map_or(0, |(b, _)| b.items.len());
        // skip migrating requests at the source: the in-flight copy in the
        // target's inbox/incoming already carries their backlog
        for r in inst
            .queues
            .iter_waiting()
            .chain(inst.queues.running().iter().filter(|r| !r.migrating))
        {
            s.add_req(r);
        }
        for p in inst.inbox.iter().chain(inst.incoming.values()) {
            s.add_req(&p.req);
        }
        for f in inst.fetching.values() {
            s.add_req(&f.req);
        }
        out.instances.push(s);
    }
    out
}

/// One elastic-controller tick, run at the barrier (the controller is
/// cluster-global — observing and flipping from inside a shard window
/// would make the result depend on the partition).
fn controller_tick(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    w: f64,
    cfg: &SimConfig,
    requests: &[RequestSpec],
) {
    let now = ctl.next_tick;
    ctl.events += 1;
    // (1) a completed flip elsewhere may have orphaned a hand-off
    // attempt: re-offer stranded requests first
    retry_stranded(shards, ctl, dirs, now, w, cfg);
    let Control { controller, tracker, inst_shard, tracer, report, next_tick, faults, .. } =
        &mut *ctl;
    let Some((cc, est, pol)) = controller.as_mut() else {
        *next_tick = f64::INFINITY;
        return;
    };

    // (2) observe queue depths + windowed latency tails (lifecycles are
    // gathered across shards in ascending id order — canonical, so the
    // observation cannot depend on the partition)
    let mut refs: Vec<(&u64, &Lifecycle)> = Vec::new();
    for s in shards.iter() {
        refs.extend(s.lifecycles.iter());
    }
    refs.sort_unstable_by_key(|(id, _)| **id);
    let failed = faults.as_ref().map(|f| f.failed.as_slice());
    let wstats = crate::metrics::window_stats(refs.iter().map(|(_, lc)| *lc), now - cc.window);
    est.observe(cluster_sample_sharded(shards, inst_shard, tracker, failed, now, &wstats));
    drop(refs);

    // (3) decide: at most one new drain per tick. Crashed instances are
    // unavailable exactly like draining ones — the estimator stripped
    // their server credit above, and the policy neither picks them as
    // donor nor counts them as stage coverage — so the controller
    // re-plans the surviving roles around the hole (and a crash/recover
    // pair cannot fight a concurrent drain-and-flip on the same
    // instance).
    if let Some(load) = est.snapshot() {
        let masks: Vec<StageMask> = (0..inst_shard.len())
            .map(|gid| inst_ref(shards, inst_shard, gid).mask)
            .collect();
        let mut unavailable = tracker.draining_flags();
        if let Some(f) = failed {
            for (u, &down) in unavailable.iter_mut().zip(f) {
                *u |= down;
            }
        }
        if let Some(d) = pol.decide(now, &load, &masks, &unavailable) {
            debug_assert!(
                !failed.is_some_and(|f| f[d.instance]),
                "policy picked crashed donor inst{} despite the unavailable flag",
                d.instance
            );
            tracker.begin(now, d.instance, d.to);
        }
    }

    // (4) progress drains: cancel expired ones, flip emptied ones
    for gid in 0..inst_shard.len() {
        if !tracker.is_draining(gid) {
            continue;
        }
        if tracker.expired(now, gid, cc.drain_timeout) {
            tracker.cancel(gid);
            continue;
        }
        let s = inst_shard[gid];
        let li = gid - shards[s].lo;
        let inst = &shards[s].instances[li];
        let empty = inst.current.is_none()
            && inst.queues.total() == 0
            && inst.inbox.is_empty()
            && inst.incoming.is_empty()
            && inst.fetching.is_empty();
        if empty {
            let to = tracker.complete(now, gid, inst.mask);
            crate::log_trace!("t={now:.6} role flip inst{gid} -> {}", to.label());
            tracer.mark(SpanKind::RoleFlip, gid, now, mask_bits(to));
            let (kv_blocks, img_blocks) = cache_blocks(&cfg.model, &cfg.device, to);
            let inst = &mut shards[s].instances[li];
            inst.mask = to;
            inst.sched = cfg.policy.make(to);
            // the instance is empty: re-partition its HBM for the new
            // role's cache mix (cached content is dropped — bank the old
            // caches' counters first, and retract every advertisement
            // wholesale)
            report.kv_stats.merge(&inst.kv.stats());
            report.img_stats.merge(&inst.img.stats());
            inst.kv = PagedCache::new(kv_blocks, KV_BLOCK, 1024);
            inst.img = PagedCache::new(img_blocks, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
            if let Some(d) = dirs.as_mut() {
                d.kv.retract_all(gid);
                d.img.retract_all(gid);
                inst.kv.set_eviction_tracking(true);
                inst.img.set_eviction_tracking(true);
            }
        }
    }

    // (5) nudge instances with queued pulls (retries may have stranded
    // them while their host was full); workers re-check admission on
    // every local event, so one Wake per backed-up inbox suffices
    for gid in 0..inst_shard.len() {
        let s = inst_shard[gid];
        let li = gid - shards[s].lo;
        if !shards[s].instances[li].inbox.is_empty() {
            shards[s].push(w, gid as u32, EvKind::Wake);
        }
    }

    // (6) keep ticking while the run is live
    let total: usize = shards.iter().map(|s| s.lifecycles.len()).sum();
    let live = total < requests.len()
        || shards
            .iter()
            .any(|s| s.lifecycles.values().any(|lc| lc.finished_at.is_none()))
        || tracker.any_draining();
    *next_tick = if live && now + cc.tick <= cfg.horizon {
        now + cc.tick
    } else {
        f64::INFINITY
    };
}

// ------------------------------------------------------------- fault plane

/// Does this instance hold *any* copy of the request — live (queued or
/// running), snapshotted (inbound pull, admitted transfer, parked fetch),
/// or just its cache blocks (a migration source whose release has not
/// landed yet)? Salvage routing must never hand such an instance a second
/// copy: the queues' id index and the caches' per-request tables both
/// assume one copy per instance.
fn holds_copy(inst: &SimInstance, id: RequestId) -> bool {
    inst.queues.running().iter().any(|r| r.spec.id == id)
        || inst.queues.iter_waiting().any(|r| r.spec.id == id)
        || inst.inbox.iter().any(|p| p.req.spec.id == id)
        || inst.incoming.contains_key(&id.0)
        || inst.fetching.contains_key(&id.0)
        || inst.kv.has_request(id)
        || inst.img.has_request(id)
}

/// Detach a rescued request's per-shard ownership (lifecycle, ready time,
/// memoized chains) from the shard that owned it and bundle everything
/// into a [`Salvage`] record for re-routing.
fn take_salvage(shards: &mut [Shard], shard_idx: usize, req: ReqState, out: &mut Vec<Salvage>) {
    let id = req.spec.id.0;
    let lc = shards[shard_idx]
        .lifecycles
        .remove(&id)
        .expect("salvaged request owns a lifecycle in its shard");
    shards[shard_idx].ready_since.remove(&id);
    let ch = shards[shard_idx].chains.remove(&id);
    out.push(Salvage { req, lc, ch });
}

/// Tear down a crashed instance: bump its epoch (stale heap events die at
/// pop), void its role, drop its in-flight batch, drain every queue, empty
/// its caches, and retract all its directory advertisements. Rescuable
/// requests are collected into `salvages` (live copies and inbound
/// snapshots) or `pending_inbox` (un-admitted offers, classified later
/// once every crash of this barrier is marked).
#[allow(clippy::too_many_arguments)]
fn crash_instance(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    gid: usize,
    w: f64,
    cfg: &SimConfig,
    salvages: &mut Vec<Salvage>,
    pending_inbox: &mut Vec<(usize, PendingPull)>,
) {
    let s = ctl.inst_shard[gid];
    let li = gid - shards[s].lo;
    ctl.tracker.cancel(gid);
    ctl.tracer.mark(SpanKind::RoleFlip, gid, w, mask_bits(StageMask::NONE));
    crate::log_trace!("t={w:.6} fault: crash inst{gid}");
    let inst = &mut shards[s].instances[li];
    inst.epoch += 1;
    inst.mask = StageMask::NONE;
    inst.sched = Box::new(NullSched);
    // the executing batch is lost; its BatchDone was stamped with the old
    // epoch and will be discarded at pop
    inst.current = None;
    let drained = inst.queues.drain_all();
    let inbox = std::mem::take(&mut inst.inbox);
    let mut incoming: Vec<(u64, PendingPull)> = inst.incoming.drain().collect();
    incoming.sort_unstable_by_key(|(id, _)| *id);
    let mut fetching: Vec<(u64, PendingFetch)> = inst.fetching.drain().collect();
    fetching.sort_unstable_by_key(|(id, _)| *id);
    // bank the dying caches' counters, then drop them: a crashed instance
    // holds nothing (the NONE-mask capacity is zero blocks either plane)
    ctl.report.kv_stats.merge(&inst.kv.stats());
    ctl.report.img_stats.merge(&inst.img.stats());
    let (kvb, imgb) = cache_blocks(&cfg.model, &cfg.device, StageMask::NONE);
    inst.kv = PagedCache::new(kvb, KV_BLOCK, 1024);
    inst.img = PagedCache::new(imgb, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
    // the dead holder must vanish from the directory before any salvage
    // routing or fetch re-validation consults it
    if let Some(d) = dirs.as_mut() {
        let dead_ads = d.kv.retract_all(gid) + d.img.retract_all(gid);
        crate::log_trace!("t={w:.6} fault: inst{gid} took {dead_ads} cached advertisements down");
        inst.kv.set_eviction_tracking(true);
        inst.img.set_eviction_tracking(true);
    }
    for r in drained {
        if r.migrating {
            // the pull target owns the live snapshot; only the source
            // copy dies with this instance
            continue;
        }
        take_salvage(shards, s, r, salvages);
    }
    for (_, f) in fetching {
        take_salvage(shards, s, f.req, salvages);
    }
    for (_, p) in incoming {
        take_salvage(shards, s, p.req, salvages);
    }
    for p in inbox {
        pending_inbox.push((gid, p));
    }
}

/// Bring a crashed instance back with the role it held at crash time and
/// fresh, empty caches (its cached content died with it — surviving
/// holders re-seed it through the normal publish path).
fn recover_instance(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    mask: StageMask,
    gid: usize,
    w: f64,
    cfg: &SimConfig,
) {
    let s = ctl.inst_shard[gid];
    let li = gid - shards[s].lo;
    crate::log_trace!("t={w:.6} fault: recover inst{gid} as {}", mask.label());
    ctl.tracer.mark(SpanKind::RoleFlip, gid, w, mask_bits(mask));
    let (kvb, imgb) = cache_blocks(&cfg.model, &cfg.device, mask);
    let inst = &mut shards[s].instances[li];
    inst.mask = mask;
    inst.sched = cfg.policy.make(mask);
    inst.kv = PagedCache::new(kvb, KV_BLOCK, 1024);
    inst.img = PagedCache::new(imgb, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
    if dirs.is_some() {
        inst.kv.set_eviction_tracking(true);
        inst.img.set_eviction_tracking(true);
    }
}

/// Route one salvaged request over the post-crash cluster. Local progress
/// is reset (the crashed instance's compute is gone); pipeline progress is
/// re-derived at redelivery from whatever surviving caches hold — attach
/// resumes at the longest locally cached prefix, and fetch-over-recompute
/// can pull content a surviving holder advertises. Cache affinity steers
/// the pick toward exactly those holders. Returns the salvage back when no
/// live instance can take it.
fn route_salvage(
    shards: &mut [Shard],
    ctl: &mut Control,
    dirs: &mut Option<DirPair>,
    failed: &[bool],
    mut s: Salvage,
    w: f64,
) -> Result<(), Salvage> {
    s.req.encoded_images = 0;
    s.req.cached_images = 0;
    s.req.prefilled = 0;
    s.req.cached_prefill = 0;
    s.req.migrating = false;
    let id = s.req.spec.id;
    let stage = s.req.stage();
    {
        let Control { rs, inst_shard, .. } = &mut *ctl;
        rs.candidates.clear();
        for gid in 0..inst_shard.len() {
            if failed[gid] {
                continue;
            }
            let inst = inst_ref(shards, inst_shard, gid);
            if inst.mask.serves(stage) && !holds_copy(inst, id) {
                rs.candidates.push(gid);
            }
        }
    }
    let ch = s.ch.clone().unwrap_or_else(|| ctl.no_chains.clone());
    build_affinity2(shards, ctl, dirs, &ch, true);
    let Some(dst) = route_pick2(shards, ctl) else { return Err(s) };
    crate::log_trace!("t={w:.6} salvage req={} -> inst{dst}", id.0);
    let sdst = ctl.inst_shard[dst];
    shards[sdst].lifecycles.insert(id.0, s.lc);
    shards[sdst].ready_since.insert(id.0, w);
    if let Some(c) = s.ch {
        shards[sdst].chains.insert(id.0, c);
    }
    shards[sdst].push(w, dst as u32, EvKind::Redeliver(Box::new(s.req)));
    ctl.pending[dst] += 1.0;
    ctl.touched.push(dst);
    Ok(())
}

/// Apply every fault event due at this barrier, in the plan's canonical
/// order. Two-phase within the barrier: first every due event mutates
/// liveness/factors (and crashes tear down and *collect* their rescuable
/// requests), then — with the complete failure picture — orphaned
/// transfers are swept, deferred inbox offers are classified, and every
/// salvaged request routes over the surviving cluster. Single-threaded
/// barrier work, so digests stay bit-identical for any shard count with
/// faults on.
fn apply_faults(shards: &mut [Shard], ctl: &mut Control, ctx: &mut Ctx, w: f64, cfg: &SimConfig) {
    let due = ctl
        .faults
        .as_ref()
        .is_some_and(|fs| fs.idx < fs.events.len() && fs.events[fs.idx].t <= w);
    if !due {
        return;
    }
    let mut fs = ctl.faults.take().expect("due implies present");
    let Ctx { dirs, faults: view, .. } = &mut *ctx;
    let mut salvages: Vec<Salvage> = Vec::new();
    let mut pending_inbox: Vec<(usize, PendingPull)> = Vec::new();
    let mut crashed_now: Vec<usize> = Vec::new();
    let mut recovered_any = false;
    while fs.idx < fs.events.len() && fs.events[fs.idx].t <= w {
        let ev = fs.events[fs.idx];
        fs.idx += 1;
        fs.applied += 1;
        match ev.kind {
            FaultKind::Crash { instance } => {
                if instance >= fs.failed.len() || fs.failed[instance] {
                    continue; // out of range / already down: no-op
                }
                fs.failed[instance] = true;
                fs.saved_masks[instance] = inst_ref(shards, &ctl.inst_shard, instance).mask;
                fs.crashes += 1;
                crashed_now.push(instance);
                crash_instance(
                    shards,
                    ctl,
                    dirs,
                    instance,
                    w,
                    cfg,
                    &mut salvages,
                    &mut pending_inbox,
                );
            }
            FaultKind::Recover { instance } => {
                if instance >= fs.failed.len() || !fs.failed[instance] {
                    continue; // never crashed: no-op
                }
                fs.failed[instance] = false;
                recovered_any = true;
                recover_instance(shards, ctl, dirs, fs.saved_masks[instance], instance, w, cfg);
            }
            FaultKind::LinkDegrade { factor } => {
                if let Some(v) = view.as_mut() {
                    v.link = factor.max(1e-6);
                }
            }
            FaultKind::Straggler { instance, factor } => {
                if let Some(v) = view.as_mut() {
                    if instance < v.slow.len() {
                        v.slow[instance] = factor.max(1e-6);
                    }
                }
            }
        }
    }

    // cross-sweep: work on LIVE instances whose source died this barrier.
    // The payload those transfers would carry no longer exists, so the
    // snapshots are salvaged (progress resets at routing).
    if !crashed_now.is_empty() {
        for gid in 0..ctl.inst_shard.len() {
            if fs.failed[gid] {
                continue;
            }
            let s = ctl.inst_shard[gid];
            let li = gid - shards[s].lo;
            // un-admitted offers from a dead source
            let mut i = 0;
            while i < shards[s].instances[li].inbox.len() {
                if fs.failed[shards[s].instances[li].inbox[i].src] {
                    let p = shards[s].instances[li].inbox.remove(i);
                    take_salvage(shards, s, p.req, &mut salvages);
                } else {
                    i += 1;
                }
            }
            // admitted transfers in flight from a dead source: release the
            // blocks reserved at admit; the landing event no-ops (entry
            // gone, `transfer_land` tolerates it)
            let mut doomed: Vec<u64> = shards[s].instances[li]
                .incoming
                .iter()
                .filter(|(_, p)| fs.failed[p.src])
                .map(|(id, _)| *id)
                .collect();
            doomed.sort_unstable();
            for id in doomed {
                let p = shards[s].instances[li].incoming.remove(&id).expect("collected above");
                shards[s].instances[li].release_all(RequestId(id));
                take_salvage(shards, s, p.req, &mut salvages);
            }
            // parked fetches sourced from the dead holder self-heal: the
            // crash retracted its advertisements, so the landing's
            // directory re-validation redirects or recomputes
        }
    }

    // offers queued at a dead target: if the source still holds its live
    // copy (alive, and not crashed-then-recovered this barrier — a crash
    // drains the queues either way), move the per-request ownership back
    // and re-offer the migration over the post-crash cluster; otherwise
    // the snapshot is all that is left — salvage it
    for (dead_gid, p) in pending_inbox {
        let src = p.src;
        let sdead = ctl.inst_shard[dead_gid];
        if !fs.failed[src] && !crashed_now.contains(&src) {
            let ssrc = ctl.inst_shard[src];
            let id = p.req.spec.id;
            if sdead != ssrc {
                if let Some(lc) = shards[sdead].lifecycles.remove(&id.0) {
                    shards[ssrc].lifecycles.insert(id.0, lc);
                }
                if let Some(t) = shards[sdead].ready_since.remove(&id.0) {
                    shards[ssrc].ready_since.insert(id.0, t);
                }
                if let Some(c) = shards[sdead].chains.remove(&id.0) {
                    shards[ssrc].chains.insert(id.0, c);
                }
            }
            let next = match p.phase {
                Phase::EpMigration => Stage::Prefill,
                _ => Stage::Decode,
            };
            barrier_migrate(shards, ctl, dirs, src, id, next, p.created, w, cfg);
        } else {
            take_salvage(shards, sdead, p.req, &mut salvages);
        }
    }

    for s in salvages {
        match route_salvage(shards, ctl, dirs, &fs.failed, s, w) {
            Ok(()) => fs.recovered += 1,
            Err(s) => {
                if fs.retry {
                    fs.parked.push(s);
                } else {
                    fs.lost += 1;
                    fs.dead.push((s.req.spec.id.0, s.lc));
                }
            }
        }
    }
    // a recovery may have brought back the stage some work was waiting
    // for: re-offer requests stranded at their source (their earlier
    // hand-off found no live target) and re-route parked salvages
    if recovered_any {
        retry_stranded(shards, ctl, dirs, w, w, cfg);
        if !fs.parked.is_empty() {
            let parked = std::mem::take(&mut fs.parked);
            for s in parked {
                match route_salvage(shards, ctl, dirs, &fs.failed, s, w) {
                    Ok(()) => fs.recovered += 1,
                    Err(s) => fs.parked.push(s),
                }
            }
        }
    }
    ctl.faults = Some(fs);
}

// ------------------------------------------------------------ worker side

/// Run one shard through one window: process every owned event with
/// `t < ctx.t1` (and within the horizon). Touches only this shard's state
/// plus the frozen `ctx` — the whole function is data-race-free by
/// construction, which is what lets windows run on parallel threads.
// invlint: hot-path
// invlint: worker-phase
fn run_window(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    budgets: &Budgets,
    requests: &[RequestSpec],
) {
    loop {
        let Some(top) = shard.heap.peek() else { break };
        if !(top.t < ctx.t1 && top.t <= ctx.horizon) {
            break;
        }
        let ev = shard.heap.pop().unwrap();
        let now = ev.t;
        shard.events += 1;
        let li = ev.inst as usize - shard.lo;
        if ev.epoch != shard.instances[li].epoch {
            // minted against a previous incarnation of this instance (a
            // fault-plan crash bumped the epoch): the state it refers to
            // died with that incarnation
            continue;
        }
        match ev.kind {
            EvKind::Deliver(i) => deliver(shard, ctx, cfg, budgets, li, i, now, requests),
            EvKind::Redeliver(r) => redeliver(shard, ctx, cfg, budgets, li, *r, now),
            EvKind::BatchDone => {
                let (batch, started) = shard.instances[li]
                    .current
                    .take()
                    .expect("BatchDone for idle instance");
                let dur = now - started;
                crate::log_trace!(
                    "t={now:.6} batch done inst{} items={} dur={dur:.6}",
                    ev.inst,
                    batch.items.len()
                );
                apply_batch(shard, cfg, li, &batch, started, dur, now);
                process_inbox(shard, ctx, cfg, li, now);
                try_start(shard, ctx, cfg, budgets, li, now);
            }
            EvKind::TransferLand { req } => {
                transfer_land(shard, li, req, now);
                process_inbox(shard, ctx, cfg, li, now);
                try_start(shard, ctx, cfg, budgets, li, now);
            }
            EvKind::FetchDone { req } => {
                crate::log_trace!("t={now:.6} fetch landed req={} at inst{}", req.0, ev.inst);
                handle_fetch_done(shard, ctx, cfg, li, req, now);
                process_inbox(shard, ctx, cfg, li, now);
                try_start(shard, ctx, cfg, budgets, li, now);
            }
            EvKind::SrcRelease { req } => {
                // §4.3 step 4: target holds the data; source releases
                shard.instances[li].queues.remove_running(req);
                shard.instances[li].release_all(req);
                process_inbox(shard, ctx, cfg, li, now);
                try_start(shard, ctx, cfg, budgets, li, now);
            }
            EvKind::Wake => {
                process_inbox(shard, ctx, cfg, li, now);
                try_start(shard, ctx, cfg, budgets, li, now);
            }
        }
    }
}

/// A routed request reaches its instance (the barrier already planted its
/// lifecycle/chains in this shard): attach cache hits, consider a
/// fetch-over-recompute, then dispatch into the queues.
#[allow(clippy::too_many_arguments)]
fn deliver(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    budgets: &Budgets,
    li: usize,
    idx: usize,
    now: f64,
    requests: &[RequestSpec],
) {
    let spec = requests[idx].clone();
    let ch = chains_entry(&mut shard.chains, shard.content_cache, &shard.no_chains, &spec);
    let mut st = ReqState::new(spec);
    if shard.content_cache {
        let Shard { instances, report, .. } = &mut *shard;
        instances[li].attach(&mut st, &ch.kv, &ch.img, report);
    }
    // fetch-over-recompute: the routed target lacks content a peer
    // advertises, and pulling it is priced below recomputing — park the
    // request until the transfer lands
    if ctx.dirs.is_some() {
        match maybe_start_fetch(shard, ctx, cfg, li, st, &ch, now) {
            None => return, // parked; FetchDone resumes it
            Some(back) => st = back,
        }
    }
    let stage = st.stage();
    if shard.instances[li].mask.serves(stage) {
        shard.instances[li].queues.push_waiting(st);
    } else {
        // cache hits advanced the request past every stage this instance
        // serves (e.g. a cached image on an E-only node): admit it and
        // hand it straight to the owner of its next stage
        let rid = st.spec.id;
        shard.instances[li].queues.push_running(st);
        request_migration(shard, li, rid, stage, now);
    }
    try_start(shard, ctx, cfg, budgets, li, now);
}

/// A salvaged request reaches its rescue instance (the barrier already
/// moved its lifecycle/chains into this shard and reset its local
/// progress). Mirrors [`deliver`]'s tail: re-attach against the rescuer's
/// caches — the request resumes at the longest prefix a surviving holder
/// kept — then consider fetch-over-recompute and dispatch normally.
fn redeliver(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    budgets: &Budgets,
    li: usize,
    mut st: ReqState,
    now: f64,
) {
    crate::log_trace!("t={now:.6} redeliver req={} at inst{}", st.spec.id.0, shard.lo + li);
    let ch = chains_entry(&mut shard.chains, shard.content_cache, &shard.no_chains, &st.spec);
    if shard.content_cache {
        let Shard { instances, report, .. } = &mut *shard;
        instances[li].attach(&mut st, &ch.kv, &ch.img, report);
    }
    if ctx.dirs.is_some() {
        match maybe_start_fetch(shard, ctx, cfg, li, st, &ch, now) {
            None => return, // parked; FetchDone resumes it
            Some(back) => st = back,
        }
    }
    let stage = st.stage();
    if shard.instances[li].mask.serves(stage) {
        shard.instances[li].queues.push_waiting(st);
    } else {
        let rid = st.spec.id;
        shard.instances[li].queues.push_running(st);
        request_migration(shard, li, rid, stage, now);
    }
    try_start(shard, ctx, cfg, budgets, li, now);
}

/// §4.3 step 1, worker side: mark the request migrating and ask the
/// barrier to route the hand-off (targeting needs the cluster view).
fn request_migration(shard: &mut Shard, li: usize, id: RequestId, next: Stage, now: f64) {
    let gid = (shard.lo + li) as u32;
    let Some(r) = shard.instances[li].queues.find_running(id) else {
        return;
    };
    if r.migrating {
        return; // hand-off already in flight
    }
    r.migrating = true;
    shard.emit(now, gid, MsgKind::MigrateReq { req: id, next });
}

/// An admitted pull's transfer lands: credit the shipped state, publish
/// the now-held content, and enter the normal scheduling path.
fn transfer_land(shard: &mut Shard, li: usize, req: RequestId, now: f64) {
    let gid = shard.lo + li;
    let Some(pull) = shard.instances[li].incoming.remove(&req.0) else {
        return;
    };
    let PendingPull { req: mut r, phase, kv_cached, created, .. } = pull;
    r.migrating = false;
    if kv_cached > 0 {
        // prefill resumes at the prefix the target held
        r.cached_prefill = r.cached_prefill.max(kv_cached);
        r.prefilled = r.prefilled.max(kv_cached);
    }
    // the target now holds this content: publish it
    if shard.content_cache {
        let ch = chains_entry(&mut shard.chains, shard.content_cache, &shard.no_chains, &r.spec);
        match phase {
            Phase::EpMigration => {
                if r.spec.image_hash.is_some() {
                    let new = shard.instances[li].img.commit_hashes(req, &ch.img);
                    if shard.dirs_on && !new.is_empty() {
                        shard.emit(now, gid as u32, MsgKind::PublishImg(new));
                    }
                }
            }
            _ => {
                let new = shard.instances[li].kv.commit_hashes(req, ch.kv_commit());
                if shard.dirs_on && !new.is_empty() {
                    shard.emit(now, gid as u32, MsgKind::PublishKv(new));
                }
            }
        }
    }
    if let Some(lc) = shard.lifecycles.get_mut(&req.0) {
        lc.add_phase(phase, now - created);
    }
    shard
        .tracer
        .span(SpanKind::from_phase(phase), gid, req.0, created, now, kv_cached as u64);
    shard.ready_since.insert(req.0, now);
    crate::log_trace!("t={now:.6} transfer done req={} -> inst{gid}", req.0);
    shard.instances[li].queues.push_running(r);
}

/// Decide whether the freshly routed request should **fetch** content a
/// peer advertises instead of recomputing it (the §4.5 reuse extension,
/// taken cluster-wide): the image-embedding and KV-prefix parts are priced
/// independently against the cost model (encode vs. transfer bytes;
/// prefill of the missing prefix vs. its KV bytes) and only taken when the
/// link is cheaper. On a fetch, blocks are reserved now, the request parks
/// in `fetching`, and one `FetchDone` event carries both parts. Returns
/// the request back when nothing is worth fetching (including when the
/// directory is off).
fn maybe_start_fetch(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    li: usize,
    st: ReqState,
    ch: &HashChains,
    now: f64,
) -> Option<ReqState> {
    let Some(dirs) = ctx.dirs.as_ref() else { return Some(st) };
    let (link_lat, link_bw) = cfg.link();
    let gid = shard.lo + li;
    let id = st.spec.id;
    let mut img_src = None;
    let mut kv_src = None;
    let mut bytes = 0.0f64;

    // image embedding part (pricing + holder in the shared helper; the
    // capacity check is planning-time only — a redirect re-plans with the
    // blocks already reserved)
    if let Some((src, fetch_bytes)) =
        img_fetch_source(dirs, &ctx.loads, cfg, gid, &st, ch, &mut shard.dir_report)
    {
        let needed = img_blocks_for(st.spec.image_tokens());
        let inst = &shard.instances[li];
        let img_need = needed.saturating_sub(inst.img.held_blocks(id));
        if inst.img_blocks_needed(&st) > 0 && img_need <= inst.img.available_blocks() {
            img_src = Some(src);
            bytes += fetch_bytes;
        }
    }

    // KV-prefix part
    if shard.instances[li].kv_tokens_needed(&st) > 0 {
        if let Some((src, to_tokens, fetch_bytes)) =
            kv_fetch_source(dirs, &ctx.loads, cfg, gid, &st, ch, &mut shard.dir_report)
        {
            let inst = &shard.instances[li];
            let kv_need =
                kv_blocks_for(to_tokens).saturating_sub(inst.kv.held_blocks(id));
            if kv_need <= inst.kv.available_blocks() {
                kv_src = Some((src, to_tokens));
                bytes += fetch_bytes;
            }
        }
    }

    if img_src.is_none() && kv_src.is_none() {
        return Some(st);
    }

    // reserve the blocks now (they are needed either way), park the
    // request, and schedule the landing
    {
        let inst = &mut shard.instances[li];
        if img_src.is_some() {
            let need = img_blocks_for(st.spec.image_tokens());
            inst.img
                .grow(id, need * IMG_BLOCK)
                .expect("capacity checked for image fetch");
        }
        if let Some((_, to_tokens)) = kv_src {
            inst.kv.grow(id, to_tokens).expect("capacity checked for kv fetch");
        }
    }
    {
        let Shard { instances, outbox, msg_seq, dirs_on, .. } = &mut *shard;
        emit_retractions(&mut instances[li], *dirs_on, outbox, msg_seq, now);
    }
    shard.dir_report.fetches += 1;
    let mut dur = link_lat + bytes / link_bw;
    if let Some(fv) = ctx.faults.as_ref() {
        // fault-plan link degradation (1.0 when healthy — exact identity)
        dur *= fv.link;
    }
    shard.push(now + dur, gid as u32, EvKind::FetchDone { req: id });
    shard.tracer.span(SpanKind::Fetch, gid, id.0, now, now + dur, bytes as u64);
    shard.instances[li].fetching.insert(
        id.0,
        PendingFetch { req: st, img_src, kv_src, redirected: false, stale_counted: false },
    );
    None
}

/// The image-embedding part of a fetch plan: the best current holder of
/// the WHOLE embedding (among maximal holders, the least-loaded — a hot
/// holder should not also serve every fetch), when pulling it is priced
/// below re-encoding. Returns `(source, payload bytes)`. Pricing and
/// holder choice only — capacity is the caller's concern (checked when
/// first planning; already reserved when a landing re-validates). Loads
/// come from the frozen window snapshot, so every shard count prices the
/// same plan.
fn img_fetch_source(
    dirs: &DirPair,
    loads: &[f64],
    cfg: &SimConfig,
    target: usize,
    st: &ReqState,
    ch: &HashChains,
    dr: &mut DirectoryReport,
) -> Option<(usize, f64)> {
    // only whole-embedding hits are useful (encode runs per image; a
    // partial block set cannot shorten it)
    if st.encoded_images >= st.spec.num_images || st.spec.image_hash.is_none() {
        return None;
    }
    let needed = img_blocks_for(st.spec.image_tokens());
    dr.queries += 1;
    let (src, blocks) = dirs.img.best_holder_by_ro(&ch.img, target, |i| loads[i])?;
    if blocks < needed {
        return None;
    }
    let (link_lat, link_bw) = cfg.link();
    let remaining = st.spec.num_images - st.encoded_images;
    let miss_tokens = remaining * st.spec.tokens_per_image;
    let fetch_bytes = crate::costmodel::ops::image_payload_bytes(&cfg.model, miss_tokens);
    let fetch_t = link_lat + fetch_bytes / link_bw;
    let recompute_t =
        exec_time(encode_cost(&cfg.model, remaining), &cfg.device) + cfg.engine_overhead;
    (fetch_t < recompute_t).then_some((src, fetch_bytes))
}

/// The KV-prefix part of a fetch plan: fetch only the delta past what the
/// local cache already served, block-aligned and leaving >= 1 token for
/// prefill to emit from. Recompute is priced as a *resumed* prefill of
/// the missing delta ([`prefill_resume_cost`]) — the real plane executes
/// exactly that op, so the fetch decision and the compute it replaces
/// stay in the same currency. Returns
/// `(source, prefix tokens fetched to, payload bytes)`.
fn kv_fetch_source(
    dirs: &DirPair,
    loads: &[f64],
    cfg: &SimConfig,
    target: usize,
    st: &ReqState,
    ch: &HashChains,
    dr: &mut DirectoryReport,
) -> Option<(usize, usize, f64)> {
    if st.prefill_remaining() == 0 {
        return None;
    }
    let cap_blocks = st.spec.prefill_tokens().saturating_sub(1) / KV_BLOCK;
    dr.queries += 1;
    let (src, blocks) = dirs.kv.best_holder_by_ro(&ch.kv, target, |i| loads[i])?;
    let to_tokens = blocks.min(cap_blocks) * KV_BLOCK;
    if to_tokens <= st.prefilled {
        return None;
    }
    let delta = to_tokens - st.prefilled;
    let (link_lat, link_bw) = cfg.link();
    let fetch_bytes =
        crate::costmodel::ops::kv_delta_payload_bytes(&cfg.model, to_tokens, st.prefilled);
    let fetch_t = link_lat + fetch_bytes / link_bw;
    let recompute_t = exec_time(prefill_resume_cost(&cfg.model, st.prefilled, delta), &cfg.device)
        + cfg.engine_overhead;
    (fetch_t < recompute_t).then_some((src, to_tokens, fetch_bytes))
}

/// Apply a landed cache fetch. The plan was decided when the request
/// arrived; by landing time the advertised holder may have evicted the
/// content (the arrival→service staleness window). Each part is validated
/// against the holder's **directory** entry (barrier-synced, so every
/// shard count sees the same history); a part that went stale is
/// re-validated and redirected to a surviving holder (one redirect per
/// fetch — a second stale landing means the directory is churning), and
/// only when no priced-worthwhile holder remains does the request fall
/// back to recomputing that part, counted in `stale_fetches`. Parts that
/// landed keep their credit either way.
fn handle_fetch_done(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    li: usize,
    req: RequestId,
    now: f64,
) {
    let gid = shard.lo + li;
    let Some(mut f) = shard.instances[li].fetching.remove(&req.0) else { return };
    let ch = chains_entry(&mut shard.chains, shard.content_cache, &shard.no_chains, &f.req.spec);
    let (link_lat, link_bw) = cfg.link();
    let mut any_stale = false;
    let mut retry = false;
    let mut retry_bytes = 0.0f64;
    let dirs = ctx.dirs.as_ref().expect("fetches require the directory");
    // image part: validate against the source's directory entry — an
    // eviction mid-flight retracts it at the next barrier
    if let Some(src) = f.img_src.take() {
        let needed = img_blocks_for(f.req.spec.image_tokens());
        if dirs.img.holder_prefix_blocks(src, &ch.img) >= needed {
            let fetched = f.req.spec.num_images - f.req.encoded_images;
            let new = shard.instances[li].img.commit_hashes(req, &ch.img);
            if shard.dirs_on && !new.is_empty() {
                shard.emit(now, gid as u32, MsgKind::PublishImg(new));
            }
            f.req.cached_images = f.req.spec.num_images;
            f.req.encoded_images = f.req.spec.num_images;
            shard.dir_report.fetched_images += fetched;
        } else if !f.redirected {
            // stale: re-validate against the current directory (the
            // blocks are already reserved locally, so only holder +
            // pricing are re-checked)
            match img_fetch_source(dirs, &ctx.loads, cfg, gid, &f.req, &ch, &mut shard.dir_report)
            {
                Some((src2, bytes)) => {
                    f.img_src = Some(src2);
                    retry_bytes += bytes;
                    retry = true;
                }
                None => any_stale = true,
            }
        } else {
            any_stale = true;
        }
    }
    // KV-prefix part
    if let Some((src, to_tokens)) = f.kv_src.take() {
        let blocks = to_tokens / KV_BLOCK;
        if dirs.kv.holder_prefix_blocks(src, &ch.kv[..blocks]) >= blocks {
            let new = shard.instances[li].kv.commit_hashes(req, &ch.kv[..blocks]);
            if shard.dirs_on && !new.is_empty() {
                shard.emit(now, gid as u32, MsgKind::PublishKv(new));
            }
            shard.dir_report.fetched_kv_tokens += to_tokens.saturating_sub(f.req.prefilled);
            f.req.cached_prefill = f.req.cached_prefill.max(to_tokens);
            f.req.prefilled = f.req.prefilled.max(to_tokens);
        } else if !f.redirected {
            match kv_fetch_source(dirs, &ctx.loads, cfg, gid, &f.req, &ch, &mut shard.dir_report)
            {
                Some((src2, to2, bytes)) => {
                    f.kv_src = Some((src2, to2));
                    retry_bytes += bytes;
                    retry = true;
                }
                None => any_stale = true,
            }
        } else {
            any_stale = true;
        }
    }
    if retry {
        shard.dir_report.redirected_fetches += 1;
    }
    // a fetch counts stale at most once, mirroring `fetches` (one
    // combined transfer per request) — even when its parts are abandoned
    // across different landings (e.g. img part gives up on landing 1
    // while the kv part redirects and fails on landing 2)
    if any_stale && !f.stale_counted {
        shard.dir_report.stale_fetches += 1;
        f.stale_counted = true;
    }
    if retry {
        f.redirected = true;
        let mut dur = link_lat + retry_bytes / link_bw;
        if let Some(fv) = ctx.faults.as_ref() {
            dur *= fv.link;
        }
        shard.push(now + dur, gid as u32, EvKind::FetchDone { req });
        shard.tracer.span(SpanKind::Fetch, gid, req.0, now, now + dur, retry_bytes as u64);
        shard.instances[li].fetching.insert(req.0, f);
        return;
    }
    // resume the normal dispatch path with whatever credit landed
    let r = f.req;
    let stage = r.stage();
    if shard.instances[li].mask.serves(stage) {
        shard.instances[li].queues.push_waiting(r);
    } else {
        shard.instances[li].queues.push_running(r);
        request_migration(shard, li, req, stage, now);
    }
}

/// Batch duration from the cost model: the LM stream (prefill chunks +
/// decode tokens, genuinely fused kernels) and the vision stream (encode),
/// combined per the multi-stream setting.
fn batch_duration(batch: &Batch, cfg: &SimConfig) -> f64 {
    let mut chunks: Vec<(usize, usize)> = Vec::new();
    let mut dctx: Vec<usize> = Vec::new();
    let mut imgs = 0usize;
    for (_, w) in &batch.items {
        match w {
            TaskWork::PrefillChunk { ctx, tokens } => chunks.push((*ctx, *tokens)),
            TaskWork::DecodeToken { ctx } => dctx.push(*ctx),
            TaskWork::Encode { images } => imgs += images,
            TaskWork::Migrate => {}
        }
    }
    // fused LM iteration: weights read once across prefill chunks + decodes
    let lm: Cost = iteration_cost(&cfg.model, &chunks, &dctx);
    let vis: Cost = encode_cost(&cfg.model, imgs);
    let mut streams: Vec<Cost> = Vec::new();
    if lm.flops > 0.0 {
        streams.push(lm);
    }
    if vis.flops > 0.0 {
        streams.push(vis);
    }
    if streams.is_empty() {
        return 0.0;
    }
    let kernel_time = if cfg.multistream {
        parallel_time(&streams, &cfg.device)
    } else {
        sequential_time(&streams, &cfg.device)
    };
    kernel_time + cfg.engine_overhead
}

fn try_start(
    shard: &mut Shard,
    ctx: &Ctx,
    cfg: &SimConfig,
    budgets: &Budgets,
    li: usize,
    now: f64,
) {
    if shard.instances[li].current.is_some() {
        return;
    }
    let gid = (shard.lo + li) as u32;
    // split-borrow: scheduler + queues + capacity checks live on the same
    // instance; temporarily move the scheduler out.
    let inst = &mut shard.instances[li];
    let mut sched = std::mem::replace(&mut inst.sched, Box::new(NullSched));
    let batch = {
        let kv = &inst.kv;
        let img = &inst.img;
        let mask = inst.mask;
        let kv_avail = kv.available_blocks();
        let img_avail = img.available_blocks();
        let mut kv_used = 0usize;
        let mut img_used = 0usize;
        let mut admit = |r: &ReqState| -> bool {
            // blocks already pinned (cached prefix) cost nothing; evictable
            // cached blocks count as capacity — backpressure only when
            // genuinely full
            let kv_need = kv_blocks_for(kv_tokens_needed_mask(mask, r))
                .saturating_sub(kv.held_blocks(r.spec.id));
            let img_need =
                img_blocks_needed_mask(mask, r).saturating_sub(img.held_blocks(r.spec.id));
            if kv_used + kv_need <= kv_avail && img_used + img_need <= img_avail {
                kv_used += kv_need;
                img_used += img_need;
                true
            } else {
                false
            }
        };
        sched.build_batch(&mut inst.queues, budgets, &mut admit)
    };
    inst.sched = sched;

    // reserve blocks for any running request not yet fully allocated.
    // Skip requests that are migrating away or whose next stage we don't
    // serve (the cache-hit bounce path admits those without a capacity
    // check — they keep only their pinned prefix until the pull lands).
    // Split borrow (queues shared / caches mut) so nothing is cloned.
    {
        let Shard { instances, chains, no_chains, content_cache, .. } = &mut *shard;
        let SimInstance { queues, kv, img, mask, .. } = &mut instances[li];
        let mask = *mask;
        for r in queues.running() {
            if r.migrating || !mask.serves(r.stage()) {
                continue;
            }
            let ch = chains_entry(chains, *content_cache, no_chains, &r.spec);
            reserve_blocks(mask, kv, img, r, &ch);
        }
    }
    // reserving may have evicted cached blocks: retract them from the
    // cluster directory before anyone queries it again
    {
        let Shard { instances, outbox, msg_seq, dirs_on, .. } = &mut *shard;
        emit_retractions(&mut instances[li], *dirs_on, outbox, msg_seq, now);
    }

    let has_compute = batch
        .items
        .iter()
        .any(|(_, w)| !matches!(w, TaskWork::Migrate));
    if !has_compute {
        return;
    }
    let mut dur = batch_duration(&batch, cfg);
    if let Some(fv) = ctx.faults.as_ref() {
        // fault-plan straggler slowdown (1.0 when healthy — exact identity)
        dur *= fv.slow[gid as usize];
    }
    shard.batches += 1;
    shard.instances[li].current = Some((batch, now));
    shard.push(now + dur, gid, EvKind::BatchDone);
}

fn kv_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    if !(mask.prefill || mask.decode) {
        return 0;
    }
    r.spec.prefill_tokens() + if mask.decode { r.spec.output_tokens } else { 0 }
}

fn img_blocks_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    let consumes = mask.encode || (mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
    if consumes {
        img_blocks_for(r.spec.image_tokens())
    } else {
        0
    }
}

/// Apply a completed batch: advance request progress, record tokens,
/// trigger migrations, finish requests.
fn apply_batch(
    shard: &mut Shard,
    cfg: &SimConfig,
    li: usize,
    batch: &Batch,
    started: f64,
    dur: f64,
    now: f64,
) {
    let gid = shard.lo + li;
    // take the scratch accumulators so later helper calls can borrow
    // `shard` mutably (returned below — allocation-free after warmup)
    let mut to_finish = std::mem::take(&mut shard.scratch.to_finish);
    let mut to_migrate = std::mem::take(&mut shard.scratch.to_migrate);
    to_finish.clear();
    to_migrate.clear();

    for (id, work) in &batch.items {
        if matches!(work, TaskWork::Migrate) {
            // pure hand-off placeholder: no compute, and the request (and
            // its lifecycle) may already live on another shard
            continue;
        }
        let mask = shard.instances[li].mask;
        let Some(r) = shard.instances[li].queues.find_running(*id) else {
            continue; // migrated away mid-flight
        };
        let lc = shard.lifecycles.get_mut(&id.0).expect("lifecycle exists");
        // single map access per item: read the ready timestamp and write
        // the new one through the same entry (always present — inserted
        // at arrival, removed only at finish)
        let rs_slot = shard.ready_since.entry(id.0).or_insert(started);
        let rs = *rs_slot;
        match work {
            TaskWork::Encode { images } => {
                r.encoded_images += images;
                lc.add_phase(Phase::EncodeQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::EncodeExec, dur);
                *rs_slot = now;
                shard.tracer.span(SpanKind::EncodeQueue, gid, id.0, rs.min(started), started, 0);
                shard.tracer.span(SpanKind::EncodeExec, gid, id.0, started, now, *images as u64);
                if r.encode_remaining() == 0 {
                    let rid = *id;
                    // publish the finished embedding for cross-request reuse
                    if shard.content_cache && r.spec.image_hash.is_some() {
                        let spec = r.spec.clone();
                        let ch = chains_entry(
                            &mut shard.chains,
                            shard.content_cache,
                            &shard.no_chains,
                            &spec,
                        );
                        let new = shard.instances[li].img.commit_hashes(rid, &ch.img);
                        if shard.dirs_on && !new.is_empty() {
                            shard.emit(now, gid as u32, MsgKind::PublishImg(new));
                        }
                    }
                    if !mask.prefill {
                        to_migrate.push((rid, Stage::Prefill));
                    }
                }
            }
            TaskWork::PrefillChunk { tokens, .. } => {
                r.prefilled += tokens;
                lc.add_phase(Phase::PrefillQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::PrefillExec, dur);
                *rs_slot = now;
                shard.tracer.span(SpanKind::PrefillQueue, gid, id.0, rs.min(started), started, 0);
                shard.tracer.span(SpanKind::PrefillExec, gid, id.0, started, now, *tokens as u64);
                if r.prefill_remaining() == 0 {
                    // prefill emits the first output token — unless this
                    // is a salvaged request re-running prefill with decode
                    // progress already banked (never reset decoded, never
                    // double-record the first token)
                    if r.decoded == 0 {
                        r.decoded = 1;
                        lc.record_token(now);
                    }
                    let rid = *id;
                    let spec = r.spec.clone();
                    // publish the shareable KV prefix for cross-request reuse
                    if shard.content_cache {
                        let ch = chains_entry(
                            &mut shard.chains,
                            shard.content_cache,
                            &shard.no_chains,
                            &spec,
                        );
                        let new = shard.instances[li].kv.commit_hashes(rid, ch.kv_commit());
                        if shard.dirs_on && !new.is_empty() {
                            shard.emit(now, gid as u32, MsgKind::PublishKv(new));
                        }
                    }
                    // image embeddings consumed: free image cache (tagged
                    // blocks stay evictable-cached for the next hit)
                    let has_img = shard.instances[li].img.has_request(rid);
                    if has_img {
                        shard.instances[li].img.free(rid).unwrap();
                    }
                    let r = shard.instances[li].queues.find_running(rid).unwrap();
                    if r.finished() {
                        to_finish.push(rid);
                    } else if !mask.decode {
                        to_migrate.push((rid, Stage::Decode));
                    }
                }
            }
            TaskWork::DecodeToken { .. } => {
                r.decoded += 1;
                lc.add_phase(Phase::DecodeQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::DecodeExec, dur);
                lc.record_token(now);
                *rs_slot = now;
                shard.tracer.span(SpanKind::DecodeQueue, gid, id.0, rs.min(started), started, 0);
                shard.tracer.span(SpanKind::DecodeExec, gid, id.0, started, now, 1);
                if r.finished() {
                    to_finish.push(*id);
                }
            }
            TaskWork::Migrate => unreachable!("skipped above"),
        }
    }

    for &id in &to_finish {
        shard.instances[li].queues.remove_running(id);
        shard.instances[li].release_all(id);
        if let Some(lc) = shard.lifecycles.get_mut(&id.0) {
            lc.finished_at = Some(now);
        }
        // finished: drop the per-request engine state (the lifecycle
        // stays — it IS the result)
        shard.ready_since.remove(&id.0);
        shard.chains.remove(&id.0);
    }

    // paper §4.3 step 1: ask the barrier to route each hand-off
    for &(id, next_stage) in &to_migrate {
        request_migration(shard, li, id, next_stage, now);
    }

    to_finish.clear();
    to_migrate.clear();
    shard.scratch.to_finish = to_finish;
    shard.scratch.to_migrate = to_migrate;
}

/// Admit pending pulls wherever capacity allows (§4.3 step 2) and schedule
/// their transfers (step 3). The transfer carries only the payload tokens
/// the target's content-addressed cache does not already hold (delta
/// transfer): reserving the pull shares any cached prefix blocks, and the
/// remaining tokens price the link time. The source's release travels as
/// a boundary message — it lands at the transfer's landing time, barrier
/// permitting.
fn process_inbox(shard: &mut Shard, ctx: &Ctx, cfg: &SimConfig, li: usize, now: f64) {
    let (link_lat, link_bw) = cfg.link();
    let gid = (shard.lo + li) as u32;
    let mut i = 0;
    while i < shard.instances[li].inbox.len() {
        let can = shard.instances[li].can_admit(&shard.instances[li].inbox[i].req);
        if !can {
            i += 1; // blocked: backpressure (source keeps its blocks)
            continue;
        }
        let mut pull = shard.instances[li].inbox.remove(i);
        let r = pull.req.clone();
        let ch = chains_entry(&mut shard.chains, shard.content_cache, &shard.no_chains, &r.spec);
        let (kv_cached, img_cached) = {
            let SimInstance { kv, img, mask, .. } = &mut shard.instances[li];
            reserve_blocks(*mask, kv, img, &r, &ch)
        };
        {
            let Shard { instances, outbox, msg_seq, dirs_on, .. } = &mut *shard;
            emit_retractions(&mut instances[li], *dirs_on, outbox, msg_seq, now);
        }
        pull.kv_cached = kv_cached;
        let cached = match pull.phase {
            Phase::EpMigration => img_cached,
            _ => kv_cached,
        };
        let cached = cached.min(pull.payload_tokens);
        shard.report.migration_tokens_saved += cached;
        let bytes = match pull.phase {
            Phase::EpMigration => crate::costmodel::ops::image_delta_payload_bytes(
                &cfg.model,
                pull.payload_tokens,
                cached,
            ),
            _ => crate::costmodel::ops::kv_delta_payload_bytes(
                &cfg.model,
                pull.payload_tokens,
                cached,
            ),
        };
        let mut dur = link_lat + bytes / link_bw;
        if let Some(fv) = ctx.faults.as_ref() {
            // fault-plan link degradation (1.0 when healthy)
            dur *= fv.link;
        }
        let land = now + dur;
        shard.push(land, gid, EvKind::TransferLand { req: r.spec.id });
        shard.emit(now, gid, MsgKind::SrcRelease { src: pull.src, req: r.spec.id, land });
        shard.tracer.span(SpanKind::Transfer, gid as usize, r.spec.id.0, now, land, bytes as u64);
        shard.instances[li].incoming.insert(r.spec.id.0, pull);
    }
}

/// Placeholder scheduler used during the split-borrow swap.
struct NullSched;
impl Scheduler for NullSched {
    fn build_batch(
        &mut self,
        _q: &mut Queues,
        _b: &Budgets,
        _a: &mut crate::scheduler::AdmitFn,
    ) -> Batch {
        Batch::default()
    }
    fn name(&self) -> &'static str {
        "null"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ControllerConfig, ModelSpec, SloSpec};
    use crate::scheduler::Policy;
    use crate::simulator::ClusterSpec;
    use crate::workload::{Dataset, PoissonGenerator};

    fn run(cluster: &str, policy: Policy, rate: f64, n: usize) -> SimResult {
        let model = ModelSpec::llava15_7b();
        let slo = SloSpec::new(0.25, 0.04);
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse(cluster).unwrap(),
            policy,
            slo,
        );
        let gen = PoissonGenerator::new(Dataset::textcaps(), rate, 42);
        let reqs = gen.generate(&model, n);
        simulate(&cfg, &reqs)
    }

    #[test]
    fn colocated_low_rate_finishes_everything() {
        let res = run("8EPD", Policy::StageLevel, 4.0, 60);
        assert_eq!(res.unfinished, 0, "all requests should finish");
        assert_eq!(res.metrics.num_finished(), 60);
        assert_eq!(res.migrations, 0, "colocated EPD never migrates");
        assert!(res.metrics.ttft().mean() > 0.0);
    }

    #[test]
    fn disaggregated_migrates_and_finishes() {
        let res = run("1E3P4D", Policy::StageLevel, 4.0, 60);
        assert_eq!(res.unfinished, 0);
        // every image request migrates E->P and P->D
        assert!(res.migrations >= 100, "migrations = {}", res.migrations);
        let bd = res.metrics.phase_breakdown();
        assert!(bd[Phase::EpMigration as usize] > 0.0);
        assert!(bd[Phase::PdMigration as usize] > 0.0);
    }

    #[test]
    fn token_latencies_monotone() {
        let res = run("1E3P4D", Policy::StageLevel, 2.0, 40);
        for lc in res.metrics.finished() {
            let t = &lc.token_times;
            assert!(t.windows(2).all(|w| w[1] >= w[0] - 1e-12));
            assert!(lc.ttft().unwrap() >= 0.0);
        }
    }

    #[test]
    fn output_token_counts_exact() {
        let model = ModelSpec::llava15_7b();
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse("8EPD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let gen = PoissonGenerator::new(Dataset::textvqa(), 2.0, 7);
        let reqs = gen.generate(&model, 30);
        let res = simulate(&cfg, &reqs);
        for spec in &reqs {
            let lc = &res.metrics.lifecycles[&spec.id.0];
            assert_eq!(
                lc.token_times.len(),
                spec.output_tokens,
                "request {} should emit exactly its output budget",
                spec.id
            );
        }
    }

    #[test]
    fn overload_degrades_attainment() {
        let lo = run("8EPD", Policy::StageLevel, 2.0, 60);
        let hi = run("8EPD", Policy::StageLevel, 200.0, 120);
        let slo = SloSpec::new(0.25, 0.04);
        let a_lo = lo.metrics.slo_attainment(slo);
        let a_hi = hi.metrics.slo_attainment(slo);
        assert!(
            a_lo > a_hi || (a_lo - a_hi).abs() < 1e-9,
            "attainment must not improve under overload: lo={a_lo} hi={a_hi}"
        );
        assert!(a_lo > 0.8, "low rate should mostly meet SLO, got {a_lo}");
    }

    #[test]
    fn stage_level_beats_prefill_first_on_tpot() {
        // the Fig. 7 story: prefill-first stalls decodes -> worse tail TPOT.
        // Single instance under real pressure so requests actually overlap.
        let ours = run("1EPD", Policy::StageLevel, 6.0, 80);
        let v0 = run("1EPD", Policy::PrefillFirst, 6.0, 80);
        let t_ours = ours.metrics.tpot().p99();
        let t_v0 = v0.metrics.tpot().p99();
        assert!(
            t_ours < t_v0,
            "stage-level p99 TPOT {t_ours} should beat prefill-first {t_v0}"
        );
    }

    #[test]
    fn incomplete_cluster_strands_requests() {
        // no prefill instance: image requests encode, then strand waiting
        // for a P node that never exists — unfinished, not dropped
        let res = run("4E4D", Policy::StageLevel, 2.0, 10);
        assert_eq!(res.metrics.num_finished(), 0);
        assert_eq!(res.unfinished, 10);
        assert_eq!(res.dropped_requests, 0);

        // text-only requests on the same cluster have NO serving candidate
        // at arrival: they are dropped, counted, and leave no
        // half-initialized lifecycle / ready_since state behind
        // (regression: they used to linger as phantom lifecycles)
        let model = ModelSpec::llava15_7b();
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse("4E4D").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let text_only = Dataset { image_prob: 0.0, ..Dataset::textcaps() };
        let reqs = PoissonGenerator::new(text_only, 2.0, 5).generate(&model, 10);
        let res = simulate(&cfg, &reqs);
        assert_eq!(res.dropped_requests, 10, "every text request is dropped");
        assert_eq!(res.unfinished, 0, "drops are not 'unfinished' work");
        assert_eq!(res.metrics.len(), 0, "no phantom lifecycles remain");
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        let b = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.migrations, b.migrations);
        assert!((a.metrics.ttft().mean() - b.metrics.ttft().mean()).abs() < 1e-12);
    }

    // ---- content-addressed reuse -----------------------------------------

    /// A request whose image and prompt prefix recur across the trace.
    fn shared_spec(id: u64, arrival: f64, prompt: usize, out: usize) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival,
            num_images: 1,
            tokens_per_image: 576,
            prompt_tokens: prompt,
            output_tokens: out,
            image_hash: Some(0xCAFE),
            shared_prefix_tokens: prompt.min(32),
            prefix_hash: 0x5157,
        }
    }

    fn sim(cluster: &str, reqs: &[RequestSpec], content_cache: bool) -> SimResult {
        let mut cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.content_cache = content_cache;
        simulate(&cfg, reqs)
    }

    #[test]
    fn repeated_content_hits_cache_and_cuts_latency() {
        let reqs: Vec<RequestSpec> =
            (0..40).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let warm = sim("1EPD", &reqs, true);
        let cold = sim("1EPD", &reqs, false);
        assert_eq!(warm.unfinished, 0);
        assert_eq!(cold.unfinished, 0);
        assert_eq!(cold.cache.img_hit_images, 0);
        assert_eq!(cold.cache.kv_hit_tokens, 0);
        // everything after the first request reuses the image embedding
        // and the shared prefix KV
        assert!(warm.cache.img_hit_images >= 35, "img hits {}", warm.cache.img_hit_images);
        assert!(
            warm.cache.kv_hit_tokens >= 35 * 576,
            "kv hit tokens {}",
            warm.cache.kv_hit_tokens
        );
        assert!(warm.cache.kv_hit_rate() > 0.5);
        // skipped encode + shortened prefill must show up in TTFT
        let (t_warm, t_cold) = (warm.metrics.ttft().mean(), cold.metrics.ttft().mean());
        assert!(t_warm < t_cold, "warm ttft {t_warm} vs cold {t_cold}");
        // identical token accounting either way
        assert_eq!(warm.metrics.num_finished(), cold.metrics.num_finished());
    }

    #[test]
    fn cold_traces_are_bit_identical_with_the_cache_enabled() {
        // all-unique content: enabling the content cache must not change
        // behaviour at all (zero regressions on cold traces)
        let model = ModelSpec::llava15_7b();
        let gen = PoissonGenerator::new(Dataset::textcaps(), 6.0, 13);
        let reqs = gen.generate(&model, 80);
        let on = sim("1E2P1D", &reqs, true);
        let off = sim("1E2P1D", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.unfinished, off.unfinished);
        assert_eq!(on.cache.kv_hit_tokens, 0);
        assert_eq!(on.cache.img_hit_images, 0);
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert!((on.metrics.tpot().mean() - off.metrics.tpot().mean()).abs() < 1e-12);
    }

    #[test]
    fn delta_transfer_skips_bytes_the_target_caches() {
        // disaggregated: the P node commits the shared prefix, the D node
        // commits migrated-in KV; later migrations transfer only deltas
        let reqs: Vec<RequestSpec> =
            (0..24).map(|i| shared_spec(i, i as f64 * 0.5, 48, 6)).collect();
        let warm = sim("1E1P1D", &reqs, true);
        assert_eq!(warm.unfinished, 0);
        assert!(
            warm.cache.migration_tokens_saved > 0,
            "deltas must save transfer tokens"
        );
        let cold = sim("1E1P1D", &reqs, false);
        assert_eq!(cold.cache.migration_tokens_saved, 0);
        assert_eq!(warm.metrics.num_finished(), cold.metrics.num_finished());
    }

    #[test]
    fn cached_image_on_encode_only_node_skips_straight_to_prefill() {
        // request 0 encodes on the E node (committing the embedding);
        // request 1 arrives later with the same image, hits the E node's
        // cache, and must hand itself to the P node without re-encoding
        let reqs = vec![shared_spec(0, 0.0, 40, 3), shared_spec(1, 5.0, 40, 3)];
        let res = sim("1E1P1D", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.cache.img_hit_images, 1);
        let bd = res.metrics.phase_breakdown();
        // only one encode execution across both requests
        assert!(bd[Phase::EncodeExec as usize] > 0.0);
        assert_eq!(res.metrics.num_finished(), 2);
    }

    #[test]
    fn sub_block_images_still_hit_the_embedding_cache() {
        // qwen2-vl-shaped images (380 tokens < IMG_BLOCK) occupy one
        // rounded-up block; acquisition must cap by occupied blocks, not
        // raw image tokens, or repeats would silently never hit
        let reqs: Vec<RequestSpec> = (0..10)
            .map(|i| {
                let mut s = shared_spec(i, i as f64 * 0.4, 24, 3);
                s.tokens_per_image = 380;
                s
            })
            .collect();
        let res = sim("1EPD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert!(
            res.cache.img_hit_images >= 8,
            "sub-block image repeats must hit, got {}",
            res.cache.img_hit_images
        );
    }

    #[test]
    fn interleaved_distinct_images_keep_correctness() {
        // 6 distinct images cycling through one instance: constant
        // hit/miss interleaving across concurrent requests must not
        // corrupt accounting — everything still finishes exactly once
        let reqs: Vec<RequestSpec> = (0..60)
            .map(|i| {
                let mut s = shared_spec(i, i as f64 * 0.2, 32, 3);
                s.image_hash = Some(0x1000 + (i % 6));
                s
            })
            .collect();
        let res = sim("1EPD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.metrics.num_finished(), 60);
        assert!(res.cache.img_hit_images > 40, "repeats hit after first sight");
    }

    // ---- cluster-wide content directory -----------------------------------

    fn sim_dir(cluster: &str, reqs: &[RequestSpec], directory: bool) -> SimResult {
        let mut cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.content_cache = true;
        cfg.cache_directory = directory;
        simulate(&cfg, reqs)
    }

    #[test]
    fn directory_affinity_matches_per_instance_scans_on_warm_traces() {
        // same warm trace, directory on vs off, on a single instance where
        // fetch can never trigger (no peers): the directory's one-sweep
        // affinity must reproduce the per-instance scans exactly
        let reqs: Vec<RequestSpec> =
            (0..40).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let on = sim_dir("1EPD", &reqs, true);
        let off = sim_dir("1EPD", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.cache.img_hit_images, off.cache.img_hit_images);
        assert_eq!(on.cache.kv_hit_tokens, off.cache.kv_hit_tokens);
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert_eq!(on.cache.directory.fetches, 0, "no peers, no fetches");
        assert!(on.cache.directory.publishes > 0, "commits are advertised");
    }

    #[test]
    fn directory_cold_traces_are_bit_identical() {
        // all-unique content: the directory stays empty, so enabling it
        // must change nothing at all — on a multi-instance cluster too
        let model = ModelSpec::llava15_7b();
        let gen = PoissonGenerator::new(Dataset::textcaps(), 6.0, 13);
        let reqs = gen.generate(&model, 80);
        let on = sim_dir("1E2P1D", &reqs, true);
        let off = sim_dir("1E2P1D", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.unfinished, off.unfinished);
        assert_eq!(on.cache.directory.fetches, 0);
        assert_eq!(on.cache.directory.publishes, 0, "unique content never publishes");
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert!((on.metrics.tpot().mean() - off.metrics.tpot().mean()).abs() < 1e-12);
    }

    #[test]
    fn hot_prefix_spillover_fetches_instead_of_reprefilling() {
        // a hot 512-token shared prefix lives on the instance that served
        // it first; affinity herds followers there until its queue passes
        // the router's load cap, and the spillover lands on the cold peer
        // — which must FETCH the prefix KV over the link (sub-ms) instead
        // of re-prefilling 512 tokens (weight-read bound, tens of ms)
        let mk = |id: u64, t: f64| RequestSpec {
            id: RequestId(id),
            arrival: t,
            num_images: 0,
            tokens_per_image: 0,
            prompt_tokens: 600,
            output_tokens: 8,
            image_hash: None,
            shared_prefix_tokens: 512,
            prefix_hash: 0xBEEF,
        };
        // one warmup seeds the prefix on exactly one instance; the dense
        // burst two seconds later herds onto that holder and spills over
        let mut reqs = vec![mk(0, 0.0)];
        for i in 1..30 {
            reqs.push(mk(i, 2.0 + i as f64 * 0.001));
        }
        let res = sim_dir("2PD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.metrics.num_finished(), 30);
        let d = res.cache.directory;
        assert!(d.fetches >= 1, "spillover must fetch, got {d:?}");
        assert!(d.fetched_kv_tokens >= KV_BLOCK);
        assert_eq!(d.stale_fetches, 0, "nothing evicts in this run");
        // the warm cluster must not be slower with fetch-over-recompute on
        let off = sim_dir("2PD", &reqs, false);
        assert_eq!(off.cache.directory.fetches, 0);
        assert!(
            res.metrics.ttft().mean() <= off.metrics.ttft().mean() * 1.05,
            "fetching must not hurt TTFT: on={} off={}",
            res.metrics.ttft().mean(),
            off.metrics.ttft().mean()
        );
    }

    // ---- fetch-plan re-validation under eviction races ---------------------

    /// One shard owning the whole cluster plus a frozen window context
    /// (same construction as `simulate`, directory on, window open to
    /// infinity so handler calls never cross a barrier).
    fn handler_shard(cfg: &SimConfig) -> (Shard, Ctx) {
        let masks = cfg.cluster.instance_masks();
        let n = masks.len();
        let instances = build_instances(cfg, &masks, true);
        let shard = build_shards(cfg, instances, 1).pop().unwrap();
        let ctx = Ctx {
            t1: f64::INFINITY,
            horizon: f64::INFINITY,
            loads: vec![0.0; n],
            dirs: Some(DirPair {
                kv: ContentDirectory::new(n),
                img: ContentDirectory::new(n),
            }),
            faults: None,
        };
        (shard, ctx)
    }

    /// Text-only spec sharing a hot 512-token prefix.
    fn prefix_spec(id: u64, prompt: usize) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: 0.0,
            num_images: 0,
            tokens_per_image: 0,
            prompt_tokens: prompt,
            output_tokens: 4,
            image_hash: None,
            shared_prefix_tokens: 512,
            prefix_hash: 0xFE7C,
        }
    }

    /// Give `inst` a small KV pool, seed `tokens` of the shared prefix as
    /// unreferenced cached blocks, and advertise them in the directory —
    /// a holder whose content a later filler allocation can evict.
    fn seed_evictable_prefix(
        inst: &mut SimInstance,
        dirs: &mut DirPair,
        ch: &HashChains,
        tokens: usize,
        seeder: u64,
    ) {
        let blocks = tokens / KV_BLOCK;
        inst.kv = PagedCache::new(blocks + 4, KV_BLOCK, 1024);
        inst.kv.set_eviction_tracking(true);
        let rid = RequestId(seeder);
        inst.kv.allocate(rid, tokens).unwrap();
        let published = inst.kv.commit_hashes(rid, &ch.kv[..blocks]);
        assert_eq!(published.len(), blocks);
        dirs.kv.publish(inst.id, &published);
        inst.kv.free(rid).unwrap(); // refs drop: cached + evictable
    }

    /// Fill `inst`'s whole small pool so every cached prefix block evicts,
    /// and retract the evictions from the directory (what the barrier's
    /// gossip drain does in a real run).
    fn evict_prefix(inst: &mut SimInstance, dirs: &mut DirPair, filler: u64) {
        let n = inst.kv.num_blocks();
        inst.kv.allocate(RequestId(filler), n * KV_BLOCK).unwrap();
        let evicted = inst.kv.drain_evicted();
        dirs.kv.retract(inst.id, &evicted);
    }

    #[test]
    fn stale_fetch_redirects_to_a_surviving_holder() {
        // Holder eviction between fetch planning (arrival) and landing
        // (service) used to burn the fetch: the landing validated against
        // the planned source only, counted `stale_fetches`, and
        // re-prefilled 512 tokens the cluster still held on ANOTHER
        // instance. Landing-time re-validation against the current
        // directory must redirect there instead — strictly fewer stale
        // fetches on this race (1 before, 0 now).
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let (mut shard, mut ctx) = handler_shard(&cfg);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut shard.instances[0], dirs, &ch, 512, 100);
            seed_evictable_prefix(&mut shard.instances[1], dirs, &ch, 512, 101);
        }

        // arrival at instance 2: plan the fetch (lowest-index holder on
        // equal loads -> source 0), park the request
        let mut st = ReqState::new(spec.clone());
        shard.chains.insert(1, ch.clone());
        {
            let Shard { instances, report, .. } = &mut shard;
            instances[2].attach(&mut st, &ch.kv, &ch.img, report);
        }
        let parked = maybe_start_fetch(&mut shard, &ctx, &cfg, 2, st, &ch, 0.0);
        assert!(parked.is_none(), "a worthwhile fetch parks the request");
        assert_eq!(shard.instances[2].fetching[&1].kv_src, Some((0, 512)));
        assert_eq!(shard.dir_report.fetches, 1);

        // the race: holder 0 evicts the prefix before the fetch lands
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            evict_prefix(&mut shard.instances[0], dirs, 900);
        }
        assert_eq!(shard.instances[0].kv.lookup_prefix(&ch.kv[..32]), 0, "content gone");

        // landing: stale source, but holder 1 survives -> redirect
        let ev = shard.heap.pop().expect("landing scheduled");
        handle_fetch_done(&mut shard, &ctx, &cfg, 2, RequestId(1), ev.t);
        assert_eq!(shard.dir_report.stale_fetches, 0, "re-validation rescued the fetch");
        assert_eq!(shard.dir_report.redirected_fetches, 1);
        assert_eq!(
            shard.instances[2].fetching[&1].kv_src,
            Some((1, 512)),
            "redirected to the surviving holder"
        );

        // second landing commits from the survivor and resumes dispatch
        let ev = shard.heap.pop().expect("redirect scheduled a new landing");
        handle_fetch_done(&mut shard, &ctx, &cfg, 2, RequestId(1), ev.t);
        assert!(shard.instances[2].fetching.is_empty());
        assert_eq!(shard.dir_report.stale_fetches, 0);
        assert_eq!(shard.dir_report.fetched_kv_tokens, 512);
        let r = shard.instances[2].queues.peek_waiting(|_| true).expect("request dispatched");
        assert_eq!(r.prefilled, 512, "prefill resumes at the fetched prefix");
    }

    #[test]
    fn stale_fetch_with_no_surviving_holder_falls_back_to_recompute() {
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let (mut shard, mut ctx) = handler_shard(&cfg);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut shard.instances[0], dirs, &ch, 512, 100);
        }
        let mut st = ReqState::new(spec.clone());
        shard.chains.insert(1, ch.clone());
        {
            let Shard { instances, report, .. } = &mut shard;
            instances[2].attach(&mut st, &ch.kv, &ch.img, report);
        }
        assert!(maybe_start_fetch(&mut shard, &ctx, &cfg, 2, st, &ch, 0.0).is_none());
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            evict_prefix(&mut shard.instances[0], dirs, 900);
        }
        let ev = shard.heap.pop().unwrap();
        handle_fetch_done(&mut shard, &ctx, &cfg, 2, RequestId(1), ev.t);
        assert_eq!(shard.dir_report.stale_fetches, 1, "no holder left: doomed fetch recomputes");
        assert_eq!(shard.dir_report.redirected_fetches, 0);
        assert_eq!(shard.dir_report.fetched_kv_tokens, 0);
        assert!(shard.instances[2].fetching.is_empty(), "request not stuck parked");
        let r = shard.instances[2].queues.peek_waiting(|_| true).expect("request dispatched");
        assert_eq!(r.prefilled, 0, "full recompute from scratch");
    }

    #[test]
    fn one_redirect_cap_prevents_chasing_a_churning_directory() {
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let (mut shard, mut ctx) = handler_shard(&cfg);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut shard.instances[0], dirs, &ch, 512, 100);
            seed_evictable_prefix(&mut shard.instances[1], dirs, &ch, 512, 101);
        }
        let mut st = ReqState::new(spec.clone());
        shard.chains.insert(1, ch.clone());
        {
            let Shard { instances, report, .. } = &mut shard;
            instances[2].attach(&mut st, &ch.kv, &ch.img, report);
        }
        assert!(maybe_start_fetch(&mut shard, &ctx, &cfg, 2, st, &ch, 0.0).is_none());
        // both holders churn away, one before each landing
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            evict_prefix(&mut shard.instances[0], dirs, 900);
        }
        let ev = shard.heap.pop().unwrap();
        handle_fetch_done(&mut shard, &ctx, &cfg, 2, RequestId(1), ev.t);
        assert_eq!(shard.dir_report.redirected_fetches, 1);
        {
            let dirs = ctx.dirs.as_mut().unwrap();
            evict_prefix(&mut shard.instances[1], dirs, 901);
        }
        let ev = shard.heap.pop().unwrap();
        handle_fetch_done(&mut shard, &ctx, &cfg, 2, RequestId(1), ev.t);
        assert_eq!(shard.dir_report.stale_fetches, 1, "second stale landing gives up");
        assert_eq!(shard.dir_report.redirected_fetches, 1, "no second redirect");
        assert!(shard.instances[2].fetching.is_empty());
        assert_eq!(
            shard.instances[2].queues.peek_waiting(|_| true).unwrap().prefilled,
            0,
            "recompute from scratch"
        );
    }

    // ---- hot-path overhaul ------------------------------------------------

    #[test]
    fn digest_pins_behaviour_and_events_are_counted() {
        let a = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        let b = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        assert_eq!(a.digest(), b.digest(), "seeded runs must be bit-identical");
        assert!(a.events > 0, "the loop processed events");
        assert_eq!(a.events, b.events, "event counts are deterministic too");
        // a different trace must produce a different fingerprint
        let c = run("1E3P4D", Policy::StageLevel, 2.0, 40);
        assert_ne!(a.digest(), c.digest(), "digest is workload-sensitive");
    }

    #[test]
    fn digest_is_stable_across_cache_and_directory_modes_on_warm_traces() {
        // single instance: the directory's one-sweep affinity must
        // reproduce the per-instance scans exactly, digest included
        let reqs: Vec<RequestSpec> =
            (0..30).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let on = sim_dir("1EPD", &reqs, true);
        let off = sim_dir("1EPD", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.metrics.num_finished(), off.metrics.num_finished());
        // no peers => no fetches either way, so even the digest agrees
        assert_eq!(on.digest(), off.digest());
    }

    // ---- sharded execution ------------------------------------------------

    fn run_sharded(cluster: &str, rate: f64, n: usize, shards: usize) -> SimResult {
        let model = ModelSpec::llava15_7b();
        let mut cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.shards = shards;
        let reqs = PoissonGenerator::new(Dataset::textcaps(), rate, 42).generate(&model, n);
        simulate(&cfg, &reqs)
    }

    #[test]
    fn digest_is_bit_identical_across_shard_counts() {
        // the tentpole contract: shards=N is a pure execution strategy —
        // every counter and every lifecycle lands on the same bits
        for cluster in ["8EPD", "1E3P4D"] {
            let base = run_sharded(cluster, 6.0, 80, 1);
            for shards in [2, 4] {
                let res = run_sharded(cluster, 6.0, 80, shards);
                assert_eq!(
                    base.digest(),
                    res.digest(),
                    "{cluster}: shards={shards} moved the digest"
                );
                assert_eq!(base.events, res.events, "{cluster} shards={shards}");
                assert_eq!(base.migrations, res.migrations, "{cluster} shards={shards}");
            }
        }
    }

    #[test]
    fn shard_count_above_instance_count_is_clamped_and_identical() {
        let base = run_sharded("1E2P1D", 5.0, 50, 1);
        let over = run_sharded("1E2P1D", 5.0, 50, 64);
        assert_eq!(base.digest(), over.digest());
    }

    #[test]
    fn explicit_window_is_stable_across_shard_counts() {
        // a coarser merge window changes fidelity deterministically, and
        // identically for every shard count
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 6.0, 42).generate(&model, 60);
        let digest = |shards: usize| {
            let mut cfg = SimConfig::new(
                model.clone(),
                ClusterSpec::parse("1E3P4D").unwrap(),
                Policy::StageLevel,
                SloSpec::new(0.25, 0.04),
            );
            cfg.shards = shards;
            cfg.window = 0.05;
            simulate(&cfg, &reqs).digest()
        };
        let d1 = digest(1);
        assert_eq!(d1, digest(2));
        assert_eq!(d1, digest(4));
    }

    #[test]
    fn sharded_digest_survives_the_controller() {
        // role flips, drains, directory resets — all barrier-side, so the
        // digest still must not move with the shard count
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 8.0, 42).generate(&model, 120);
        let digest = |shards: usize| {
            let mut cfg = SimConfig::new(
                model.clone(),
                ClusterSpec::parse("1E3P4D").unwrap(),
                Policy::StageLevel,
                SloSpec::new(0.25, 0.04),
            );
            cfg.controller = Some(ControllerConfig {
                tick: 0.5,
                window: 8.0,
                min_samples: 4,
                sustain_ticks: 3,
                cooldown: 4.0,
                ..Default::default()
            });
            cfg.shards = shards;
            simulate(&cfg, &reqs).digest()
        };
        let d1 = digest(1);
        assert_eq!(d1, digest(2), "controller run moved at shards=2");
        assert_eq!(d1, digest(4), "controller run moved at shards=4");
    }

    #[test]
    fn traced_sharded_run_matches_untraced_digest() {
        // PR 6 invariant under parallelism: observation never reschedules,
        // on any shard count
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 6.0, 42).generate(&model, 60);
        let mk = |trace: bool, shards: usize| {
            let mut cfg = SimConfig::new(
                model.clone(),
                ClusterSpec::parse("1E3P4D").unwrap(),
                Policy::StageLevel,
                SloSpec::new(0.25, 0.04),
            );
            cfg.trace = trace;
            cfg.shards = shards;
            simulate(&cfg, &reqs)
        };
        let plain = mk(false, 1);
        let traced = mk(true, 4);
        assert_eq!(plain.digest(), traced.digest(), "tracing moved a sharded digest");
        assert!(!traced.trace.is_empty(), "tracing on captured spans");
        // and the sharded trace is deterministic: same spans both times
        let again = mk(true, 4);
        assert_eq!(traced.trace.len(), again.trace.len());
    }

    // ---- fault plane (PR 9) ----------------------------------------------

    use crate::faults::{FaultEvent, FaultPlan};

    fn fault_cfg(cluster: &str, plan: FaultPlan, shards: usize) -> SimConfig {
        let mut cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.faults = plan;
        cfg.shards = shards;
        cfg
    }

    #[test]
    fn empty_fault_plan_is_behaviourally_invisible() {
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 6.0, 42).generate(&model, 60);
        let plain = simulate(&fault_cfg("1E3P4D", FaultPlan::default(), 1), &reqs);
        let explicit =
            simulate(&fault_cfg("1E3P4D", FaultPlan { events: vec![], retry: false }, 4), &reqs);
        assert_eq!(plain.digest(), explicit.digest(), "empty plan moved the digest");
        assert_eq!(plain.fault_events, 0);
        assert_eq!(plain.crashes, 0);
        assert_eq!(plain.lost_requests, 0);
        assert_eq!(plain.recovered_requests, 0);
    }

    /// A long-decoding request with unique content (no cross-request
    /// sharing): decodes span seconds, so a mid-run crash is guaranteed to
    /// catch work in flight.
    fn long_spec(id: u64, arrival: f64) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival,
            num_images: 1,
            tokens_per_image: 576,
            prompt_tokens: 32,
            output_tokens: 600,
            image_hash: Some(0xBEEF ^ id),
            shared_prefix_tokens: 0,
            prefix_hash: id,
        }
    }

    #[test]
    fn per_role_crash_trace_loses_nothing() {
        // the PR 9 acceptance trace: one crash per stage role mid-run,
        // each recovering later, survivors guaranteed by construction —
        // every in-flight request must be salvaged and finish
        let reqs: Vec<RequestSpec> = (0..24).map(|i| long_spec(i, i as f64 * 0.05)).collect();
        let masks = ClusterSpec::parse("2E2P4D").unwrap().instance_masks();
        let plan = FaultPlan::per_role_crashes(&masks, 1.0, 0.5, 1.0, 7);
        assert_eq!(plan.events.len(), 6, "3 crashes + 3 recoveries");
        let res = simulate(&fault_cfg("2E2P4D", plan, 1), &reqs);
        assert_eq!(res.crashes, 3);
        assert_eq!(res.fault_events, 6, "every due event applies exactly once");
        assert_eq!(res.lost_requests, 0, "a survivor per stage means nothing is lost");
        assert!(res.recovered_requests > 0, "crashes mid-run must salvage something");
        assert_eq!(res.unfinished, 0, "salvaged requests must still finish");
        assert_eq!(res.dropped_requests, 0);
    }

    #[test]
    fn faulty_digest_is_stable_across_shard_counts() {
        // crashes, recoveries, a straggler, and a link-degradation window,
        // all riding the barrier protocol: shards=N must stay bit-identical
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 6.0, 42).generate(&model, 80);
        let masks = ClusterSpec::parse("2E2P4D").unwrap().instance_masks();
        let mut plan = FaultPlan::per_role_crashes(&masks, 0.5, 0.5, 1.0, 11);
        plan.events.push(FaultEvent {
            t: 0.25,
            kind: FaultKind::Straggler { instance: 7, factor: 3.0 },
        });
        plan.events.push(FaultEvent { t: 0.75, kind: FaultKind::LinkDegrade { factor: 2.0 } });
        plan.events.push(FaultEvent { t: 2.5, kind: FaultKind::LinkDegrade { factor: 1.0 } });
        let d = |shards: usize| simulate(&fault_cfg("2E2P4D", plan.clone(), shards), &reqs);
        let r1 = d(1);
        assert!(r1.crashes >= 1);
        assert_eq!(r1.digest(), d(2).digest(), "faulty run moved at shards=2");
        assert_eq!(r1.digest(), d(4).digest(), "faulty run moved at shards=4");
    }

    #[test]
    fn faulty_run_with_the_controller_stays_shard_stable() {
        // the controller now observes the fault plane (crashed instances
        // sample as unavailable and are excluded from decide()) — all of
        // it barrier-side state, so faults + elastic control together
        // must still be bit-identical at every shard count
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 6.0, 42).generate(&model, 80);
        let masks = ClusterSpec::parse("2E2P4D").unwrap().instance_masks();
        let plan = FaultPlan::per_role_crashes(&masks, 0.5, 0.5, 2.0, 11);
        let d = |shards: usize| {
            let mut cfg = fault_cfg("2E2P4D", plan.clone(), shards);
            cfg.controller = Some(ControllerConfig {
                tick: 0.5,
                window: 8.0,
                min_samples: 4,
                sustain_ticks: 3,
                cooldown: 4.0,
                ..Default::default()
            });
            simulate(&cfg, &reqs)
        };
        let r1 = d(1);
        assert!(r1.crashes >= 1);
        assert_eq!(r1.lost_requests, 0, "controller + faults still lose nothing");
        assert_eq!(r1.digest(), d(2).digest(), "controller+faults moved at shards=2");
        assert_eq!(r1.digest(), d(4).digest(), "controller+faults moved at shards=4");
    }

    #[test]
    fn straggler_and_link_degradation_slow_but_complete() {
        let model = ModelSpec::llava15_7b();
        let reqs = PoissonGenerator::new(Dataset::textcaps(), 4.0, 42).generate(&model, 40);
        let mut plan = FaultPlan::default();
        plan.events.push(FaultEvent {
            t: 0.0,
            kind: FaultKind::Straggler { instance: 0, factor: 5.0 },
        });
        plan.events.push(FaultEvent { t: 0.0, kind: FaultKind::LinkDegrade { factor: 4.0 } });
        let slow = simulate(&fault_cfg("1E3P4D", plan, 1), &reqs);
        let healthy = simulate(&fault_cfg("1E3P4D", FaultPlan::default(), 1), &reqs);
        assert_eq!(slow.unfinished, 0, "slowdowns delay, never strand");
        assert_eq!(slow.lost_requests, 0);
        assert_eq!(slow.crashes, 0);
        assert_eq!(slow.metrics.num_finished(), healthy.metrics.num_finished());
        // instance 0 is the sole encoder: a 5x straggler must show in TTFT
        assert!(
            slow.metrics.ttft().mean() > healthy.metrics.ttft().mean(),
            "straggler ttft {} vs healthy {}",
            slow.metrics.ttft().mean(),
            healthy.metrics.ttft().mean()
        );
    }

    #[test]
    fn retry_parks_across_a_stage_outage_and_retry_off_abandons() {
        // crash the only decode server mid-decode: salvaged decode work
        // has no live candidate until the recovery brings the stage back
        let reqs: Vec<RequestSpec> = (0..16).map(|i| long_spec(i, i as f64 * 0.01)).collect();
        let plan = |retry: bool| FaultPlan {
            events: vec![
                FaultEvent { t: 1.0, kind: FaultKind::Crash { instance: 2 } },
                FaultEvent { t: 3.0, kind: FaultKind::Recover { instance: 2 } },
            ],
            retry,
        };
        let kept = simulate(&fault_cfg("1E1P1D", plan(true), 1), &reqs);
        assert_eq!(kept.crashes, 1);
        assert_eq!(kept.lost_requests, 0, "retry + recovery loses nothing");
        assert!(kept.recovered_requests > 0);
        assert_eq!(kept.unfinished, 0);
        let abandoned = simulate(&fault_cfg("1E1P1D", plan(false), 1), &reqs);
        assert!(
            abandoned.lost_requests > 0,
            "retries off: mid-outage salvage with no candidate is abandoned"
        );
        // conservation: every routed request ends finished or unfinished
        // (lost ones are a subset of unfinished), none vanish
        assert!(abandoned.lost_requests <= abandoned.unfinished);
        assert_eq!(
            abandoned.metrics.num_finished() + abandoned.unfinished
                + abandoned.dropped_requests,
            16,
            "request conservation with retries off"
        );
    }
}
