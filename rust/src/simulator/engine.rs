//! The discrete-event engine: instances, migrations, and the event loop.
//!
//! # Hot-path invariants (the `bench_sim_hotpath` contract)
//!
//! The event loop is the substrate every figure-level bench and scaling
//! experiment runs on, so its per-event cost must stay O(1)-ish and
//! allocation-free:
//!
//! * **Hash once.** A request's content-hash chains ([`HashChains`]) are
//!   derived exactly once, when it enters the system, and shared via
//!   `Arc` — routing, commits, migration targeting, and fetch planning
//!   all borrow the same chains. Never call `content::spec_*_hashes`
//!   from event handlers; go through `EngineState::chains_for`.
//! * **Reuse scratch.** Candidate lists, affinity scores, and directory
//!   prefix sweeps write into `Scratch` buffers that live for the whole
//!   run. Event handlers must not allocate per event.
//! * **Index, don't scan.** Queue membership questions go through the
//!   `Queues` id → slot index and per-stage FIFOs; hot maps use the
//!   in-crate Fx hasher (`util::fxhash`), which also makes iteration
//!   order — and therefore seeded runs — deterministic across processes.
//!
//! [`SimResult::digest`] fingerprints a run's observable behaviour; the
//! golden-determinism suite pins digests for seeded traces so refactors
//! of this file can prove themselves behaviour-preserving.

use std::collections::BinaryHeap;
use std::sync::Arc;

use crate::controller::{
    ClusterSample, DrainTracker, InstanceSample, ReconfigEvent, ReconfigPolicy,
    StageLoadEstimator, StageRates,
};
use crate::core::{Lifecycle, Phase, RequestId, RequestSpec, Stage};
use crate::costmodel::{
    encode_cost, exec_time, iteration_cost, parallel_time, prefill_resume_cost, sequential_time,
    Cost,
};
use crate::metrics::RunMetrics;
use crate::obs::trace::{mask_bits, SpanKind, Tracer};
use crate::cache::{
    BlockHash, CacheStats, ContentDirectory, HashChains, PagedCache, COST_IMAGE,
};
use crate::router::{RoutePolicy, Router};
use crate::scheduler::{
    compute_image_budget, compute_token_budget, Batch, BudgetProfile, Budgets, Queues, ReqState,
    Scheduler, StageMask, TaskWork,
};
use crate::simulator::{
    cache_blocks, img_blocks_for, kv_blocks_for, SimConfig, IMG_BLOCK, KV_BLOCK,
};
use crate::util::fxhash::FxHashMap;

// ---------------------------------------------------------------- events

#[derive(Debug)]
enum EvKind {
    Arrival(usize),
    BatchDone(usize),
    TransferDone { src: usize, dst: usize, req: RequestId },
    /// A standalone cache fetch (fetch-over-recompute) landed at `dst`:
    /// the request parked in `SimInstance::fetching` resumes with the
    /// fetched content credited, or falls back to recompute when the
    /// advertised holder no longer has it (staleness).
    FetchDone { dst: usize, req: RequestId },
    /// Periodic elastic-controller evaluation (only when enabled).
    ControllerTick,
}

#[derive(Debug)]
struct Ev {
    t: f64,
    seq: u64,
    kind: EvKind,
}

// Heap ordering only needs (t, seq) — `seq` is unique, so equality on the
// key pair is a genuine equivalence and `EvKind` needs no `PartialEq`
// (nor `Clone`: events are moved, never copied).
impl PartialEq for Ev {
    fn eq(&self, other: &Self) -> bool {
        self.seq == other.seq && self.t.total_cmp(&other.t).is_eq()
    }
}
impl Eq for Ev {}
impl PartialOrd for Ev {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Ev {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // min-heap via reverse comparison
        other
            .t
            .total_cmp(&self.t)
            .then(other.seq.cmp(&self.seq))
    }
}

// -------------------------------------------------------------- instances

/// A migration waiting for the target to pull it (paper §4.3 step 1).
/// Transfer bytes are decided at *admit* time, when the target knows how
/// much of the payload its content-addressed cache already holds (delta
/// transfer — a block the target caches never crosses the link).
#[derive(Debug, Clone)]
struct PendingPull {
    req: ReqState,
    src: usize,
    phase: Phase, // EpMigration or PdMigration
    /// Payload size in content tokens (image tokens for EP, prefill
    /// tokens for PD) before any target-side cache credit.
    payload_tokens: usize,
    /// KV tokens the target already held when it admitted the pull.
    kv_cached: usize,
    created: f64,
}

/// A fetch-over-recompute transfer in flight: the routed target lacked
/// content a peer's cache holds, and the cost model priced pulling it
/// below recomputing (encode for image blocks, prefill for KV prefixes).
/// Unlike a migration pull, the request never leaves the target — it is
/// parked here until the transfer lands, blocks already reserved.
#[derive(Debug, Clone)]
struct PendingFetch {
    req: ReqState,
    /// Peer shipping the image-embedding blocks, if that part was priced
    /// worth fetching.
    img_src: Option<usize>,
    /// Peer shipping the KV prefix, and the prefix length (tokens, block
    /// aligned) the fetch extends the local cached prefix to.
    kv_src: Option<(usize, usize)>,
    /// The plan was already re-validated once after a stale landing
    /// (holder evicted mid-flight) and redirected to a surviving holder.
    /// One redirect per fetch: a second stale landing falls back to
    /// recompute instead of chasing a churning directory.
    redirected: bool,
    /// This fetch already contributed to `stale_fetches` (an abandoned
    /// part on an earlier landing); a later landing must not count it
    /// again — `stale_fetches` stays at most one per fetch, mirroring
    /// `fetches`.
    stale_counted: bool,
}

/// The cluster-wide content directory pair (KV + image planes) plus the
/// fetch counters accumulated while it drives decisions.
struct DirState {
    kv: ContentDirectory,
    img: ContentDirectory,
    report: DirectoryReport,
}

impl DirState {
    /// Drain an instance's eviction log into directory retractions. Must
    /// run after every cache-mutating step so directory answers stay
    /// exactly equal to the per-instance index scans they replace.
    fn sync_evictions(&mut self, inst: &mut SimInstance) {
        let kv = inst.kv.drain_evicted();
        if !kv.is_empty() {
            self.kv.retract(inst.id, &kv);
        }
        let img = inst.img.drain_evicted();
        if !img.is_empty() {
            self.img.retract(inst.id, &img);
        }
    }
}

struct SimInstance {
    id: usize,
    mask: StageMask,
    sched: Box<dyn Scheduler>,
    queues: Queues,
    kv: PagedCache,
    img: PagedCache,
    /// Batch currently executing (None = idle) + its start time.
    current: Option<(Batch, f64)>,
    /// Inbound migrations not yet admitted (queue = backpressure).
    inbox: Vec<PendingPull>,
    /// Admitted pulls whose transfer is in flight.
    incoming: FxHashMap<u64, PendingPull>,
    /// Requests parked while a cache fetch is in flight (directory mode).
    fetching: FxHashMap<u64, PendingFetch>,
}

impl SimInstance {
    fn load(&self) -> f64 {
        self.queues.total() as f64
            + self.inbox.len() as f64
            + self.incoming.len() as f64
            + self.fetching.len() as f64
            + self.kv.utilization() * 4.0
            + self.img.utilization()
    }

    /// Blocks this request needs on an instance with our mask (delegates
    /// to the mask-level formula `reserve_blocks` also uses — admission
    /// and reservation must never drift apart).
    fn kv_tokens_needed(&self, r: &ReqState) -> usize {
        kv_tokens_needed_mask(self.mask, r)
    }

    fn img_blocks_needed(&self, r: &ReqState) -> usize {
        img_blocks_needed_mask(self.mask, r)
    }

    /// Admission check. Blocks the request already pinned (a cached
    /// prefix acquired at attach) cost nothing; evictable cached blocks
    /// count as reclaimable — only genuine pressure backpressures.
    fn can_admit(&self, r: &ReqState) -> bool {
        let kv_need = kv_blocks_for(self.kv_tokens_needed(r))
            .saturating_sub(self.kv.held_blocks(r.spec.id));
        let img_need = self
            .img_blocks_needed(r)
            .saturating_sub(self.img.held_blocks(r.spec.id));
        kv_need <= self.kv.available_blocks() && img_need <= self.img.available_blocks()
    }

    /// Pin whatever the content-addressed caches already hold for a newly
    /// routed request, and derive its pipeline progress from the hits: a
    /// cached embedding skips encode, a cached KV prefix starts prefill
    /// mid-prompt (always leaving >= 1 token so prefill emits the first
    /// output token). Must run before the scheduler first sees `r`.
    fn attach(
        &mut self,
        r: &mut ReqState,
        kv_hashes: &[BlockHash],
        img_hashes: &[BlockHash],
        report: &mut CacheReport,
    ) {
        let id = r.spec.id;
        let img_need = self.img_blocks_needed(r);
        if img_need > 0 && !self.img.has_request(id) {
            // cap in *occupied blocks*, not raw image tokens: an image
            // smaller than IMG_BLOCK (e.g. qwen2-vl's 380 tokens) still
            // occupies — and is cached as — one whole block
            let cached = self
                .img
                .acquire_prefix(id, img_hashes, img_need * IMG_BLOCK)
                .expect("fresh request");
            let per = r.spec.tokens_per_image.max(1);
            let imgs = (cached / per).min(r.spec.num_images);
            r.cached_images = imgs;
            r.encoded_images = r.encoded_images.max(imgs);
            report.img_hit_images += imgs;
            report.img_total_images += r.spec.num_images;
        }
        if self.kv_tokens_needed(r) > 0 && !self.kv.has_request(id) {
            let cap = r.spec.prefill_tokens().saturating_sub(1);
            let cached = self
                .kv
                .acquire_prefix(id, kv_hashes, cap)
                .expect("fresh request");
            r.cached_prefill = cached;
            r.prefilled = r.prefilled.max(cached);
            report.kv_hit_tokens += cached;
            report.kv_lookup_tokens += cap;
        }
    }

    fn release_all(&mut self, id: RequestId) {
        if self.kv.has_request(id) {
            self.kv.free(id).unwrap();
        }
        if self.img.has_request(id) {
            self.img.free(id).unwrap();
        }
    }
}

// ----------------------------------------------------------------- engine

/// Cross-request reuse accounting for one simulation run.
#[derive(Debug, Default, Clone, Copy)]
pub struct CacheReport {
    /// Prefill tokens served from cached KV prefixes at attach.
    pub kv_hit_tokens: usize,
    /// Prefill tokens that were eligible for prefix reuse (sum of
    /// per-request prefill length minus the always-recomputed last token).
    pub kv_lookup_tokens: usize,
    /// Images whose embeddings were cache hits (encode skipped).
    pub img_hit_images: usize,
    pub img_total_images: usize,
    /// Migration payload tokens never transferred (target already held
    /// them — delta transfer).
    pub migration_tokens_saved: usize,
    /// Aggregated per-instance KV-cache counters.
    pub kv_stats: CacheStats,
    /// Aggregated per-instance image-cache counters.
    pub img_stats: CacheStats,
    /// Cluster-wide content-directory counters (zero when disabled).
    pub directory: DirectoryReport,
}

/// Content-directory accounting for one simulation run: how often the
/// cluster-wide view was consulted, kept current, and converted into
/// fetch-over-recompute transfers.
#[derive(Debug, Default, Clone, Copy)]
pub struct DirectoryReport {
    /// Prefix/holder sweeps answered (routing + fetch decisions).
    pub queries: u64,
    /// (hash, holder) advertisements published.
    pub publishes: u64,
    /// (hash, holder) advertisements withdrawn (evictions, role flips).
    pub retractions: u64,
    /// Cache fetches taken instead of recomputing.
    pub fetches: usize,
    /// Image embeddings served by peer fetch (encode skipped).
    pub fetched_images: usize,
    /// KV prefix tokens served by peer fetch (prefill shortened).
    pub fetched_kv_tokens: usize,
    /// Fetch landings that abandoned at least one part because the
    /// advertised holder evicted the content AND no surviving holder
    /// remained (or the fetch was already redirected once) — the request
    /// fell back to recomputing that part (staleness).
    pub stale_fetches: usize,
    /// Stale landings rescued by re-validating the plan against the
    /// *current* directory and redirecting to a surviving holder — each
    /// of these would have been a `stale_fetches` recompute before the
    /// landing-time re-validation existed.
    pub redirected_fetches: usize,
}

impl CacheReport {
    /// Fraction of reuse-eligible prefill tokens served from cache.
    pub fn kv_hit_rate(&self) -> f64 {
        if self.kv_lookup_tokens == 0 {
            0.0
        } else {
            self.kv_hit_tokens as f64 / self.kv_lookup_tokens as f64
        }
    }
    /// Fraction of images whose encode was skipped.
    pub fn img_hit_rate(&self) -> f64 {
        if self.img_total_images == 0 {
            0.0
        } else {
            self.img_hit_images as f64 / self.img_total_images as f64
        }
    }
}

/// Simulation output: metrics + counters for sanity checks and reports.
#[derive(Debug)]
pub struct SimResult {
    pub metrics: RunMetrics,
    pub migrations: usize,
    pub batches: usize,
    /// Discrete events processed by the loop (the `bench_sim_hotpath`
    /// throughput denominator: events/sec measures engine speed
    /// independently of how much simulated time a trace covers).
    pub events: u64,
    /// Requests still unfinished at the horizon.
    pub unfinished: usize,
    /// Requests no instance could serve, dropped at arrival (they create
    /// no lifecycle and are excluded from latency metrics — this counter
    /// is their only trace).
    pub dropped_requests: usize,
    /// Completed online role flips (0 when the controller is off).
    pub reconfigs: usize,
    /// Flip history: when, which instance, from which role to which.
    pub reconfig_events: Vec<ReconfigEvent>,
    /// Content-addressed cache reuse accounting.
    pub cache: CacheReport,
    /// Flight-recorder spans (empty unless `SimConfig::trace`); export
    /// with [`SimResult::trace_json`]. Excluded from [`SimResult::digest`]
    /// — observation must never look like a behaviour change.
    pub trace: Vec<crate::obs::trace::Span>,
    /// Spans overwritten in the ring (0 = the whole run fit).
    pub trace_dropped: u64,
}

impl SimResult {
    /// Order-independent fingerprint of a run's observable behaviour:
    /// every lifecycle (phase times, token timestamps, completion) folded
    /// in ascending request-id order, plus the run counters. Two runs are
    /// behaviourally identical iff their digests match — the golden
    /// determinism suite pins these for seeded traces, and perf refactors
    /// of the engine must keep them bit-identical.
    ///
    /// `events` is deliberately excluded: it fingerprints the *engine's
    /// internal step count*, not request-visible behaviour.
    pub fn digest(&self) -> u64 {
        use crate::cache::content::mix;
        let mut ids: Vec<u64> = self.metrics.lifecycles.keys().copied().collect();
        ids.sort_unstable();
        let mut h = mix(0x5eed, ids.len() as u64);
        for id in ids {
            let lc = &self.metrics.lifecycles[&id];
            h = mix(h, id);
            h = mix(h, lc.arrival.to_bits());
            for p in &lc.phase_time {
                h = mix(h, p.to_bits());
            }
            h = mix(h, lc.first_token_at.map_or(1, |t| t.to_bits()));
            h = mix(h, lc.finished_at.map_or(2, |t| t.to_bits()));
            h = mix(h, lc.token_times.len() as u64);
            for t in &lc.token_times {
                h = mix(h, t.to_bits());
            }
        }
        for v in [
            self.migrations as u64,
            self.batches as u64,
            self.unfinished as u64,
            self.dropped_requests as u64,
            self.reconfigs as u64,
            self.cache.kv_hit_tokens as u64,
            self.cache.kv_lookup_tokens as u64,
            self.cache.img_hit_images as u64,
            self.cache.img_total_images as u64,
            self.cache.migration_tokens_saved as u64,
            self.cache.directory.fetches as u64,
            self.cache.directory.fetched_kv_tokens as u64,
            self.cache.directory.fetched_images as u64,
            self.cache.directory.stale_fetches as u64,
            self.cache.directory.redirected_fetches as u64,
        ] {
            h = mix(h, v);
        }
        h
    }

    /// The recorded spans as Chrome trace-event JSON (Perfetto-loadable).
    pub fn trace_json(&self) -> crate::util::json::Json {
        crate::obs::trace::chrome_trace_json(&self.trace)
    }
}

/// Scratch buffers reused across events — the event loop's guarantee of
/// allocation-free routing/affinity decisions. Each buffer is cleared by
/// its producer before use; contents never survive an event.
#[derive(Default)]
struct Scratch {
    /// Instance ids eligible for the current routing decision.
    candidates: Vec<usize>,
    /// Cache-affinity score per candidate (parallel to `candidates`).
    affinity: Vec<f64>,
    /// Drain-gated (then raw) loads per candidate.
    gated: Vec<f64>,
    /// Directory sweep output, KV plane (indexed by instance id).
    kv_pfx: Vec<usize>,
    /// Directory sweep output, image plane.
    img_pfx: Vec<usize>,
    /// Requests finishing in the batch being applied.
    to_finish: Vec<RequestId>,
    /// Requests migrating out of the batch being applied.
    to_migrate: Vec<(RequestId, Stage)>,
}

/// All mutable engine state one event handler may touch, bundled so
/// helpers take `(&mut [SimInstance], &mut EngineState)` instead of a
/// dozen loose arguments, and so scratch buffers + memoized hash chains
/// live for the whole run.
struct EngineState<'a> {
    cfg: &'a SimConfig,
    budgets: Budgets,
    router: Router,
    tracker: DrainTracker,
    /// Cluster-wide content directory (None = per-instance affinity).
    dirs: Option<DirState>,
    heap: BinaryHeap<Ev>,
    seq: u64,
    events: u64,
    migrations: usize,
    batches: usize,
    dropped: usize,
    report: CacheReport,
    lifecycles: FxHashMap<u64, Lifecycle>,
    ready_since: FxHashMap<u64, f64>,
    /// Hash-once memo: request id -> its content-hash chains. Entries are
    /// inserted at arrival and dropped at finish; `chains_for` re-derives
    /// on a miss so late touchpoints can never observe different hashes.
    chains: FxHashMap<u64, Arc<HashChains>>,
    /// Shared empty chains for content-cache-off runs (no hashing at all).
    no_chains: Arc<HashChains>,
    scratch: Scratch,
    /// Stage-span flight recorder. Off (`Tracer::off`) unless
    /// `SimConfig::trace`: every emission below is then a single `None`
    /// branch, and recording never feeds back into scheduling.
    tracer: Tracer,
}

impl EngineState<'_> {
    fn push(&mut self, t: f64, kind: EvKind) {
        self.seq += 1;
        self.heap.push(Ev { t, seq: self.seq, kind });
    }

    /// The memoized hash chains for `spec` (hash-once rule). Off-cache
    /// runs get the shared empty chains without touching the map.
    fn chains_for(&mut self, spec: &RequestSpec) -> Arc<HashChains> {
        chains_entry(&mut self.chains, self.cfg.content_cache, &self.no_chains, spec)
    }
}

/// Field-level version of [`EngineState::chains_for`] for call sites that
/// already hold disjoint borrows of other `EngineState` fields.
fn chains_entry(
    chains: &mut FxHashMap<u64, Arc<HashChains>>,
    content_cache: bool,
    no_chains: &Arc<HashChains>,
    spec: &RequestSpec,
) -> Arc<HashChains> {
    if !content_cache {
        return no_chains.clone();
    }
    chains
        .entry(spec.id.0)
        .or_insert_with(|| Arc::new(HashChains::of_spec(spec, KV_BLOCK, IMG_BLOCK)))
        .clone()
}

/// Reserve blocks for an admitted request (must follow `can_admit`).
/// Returns (KV tokens, image tokens) already present locally — the
/// delta-transfer credit for migrated-in requests. Free function over the
/// split-borrowed cache fields so callers can iterate `queues.running()`
/// without cloning each request.
fn reserve_blocks(
    mask: StageMask,
    kv: &mut PagedCache,
    img: &mut PagedCache,
    r: &ReqState,
    ch: &HashChains,
) -> (usize, usize) {
    let id = r.spec.id;
    let mut kv_cached = 0;
    let mut img_cached = 0;
    let kv_tokens = kv_tokens_needed_mask(mask, r);
    if kv_tokens > 0 {
        if !kv.has_request(id) {
            kv_cached = kv
                .acquire_prefix(id, &ch.kv, r.spec.prefill_tokens().saturating_sub(1))
                .expect("fresh table");
        }
        kv.grow(id, kv_tokens).expect("can_admit checked kv capacity");
    }
    let img_need = img_blocks_needed_mask(mask, r);
    if img_need > 0 {
        if !img.has_request(id) {
            // occupied-block cap (sub-block images round up, see attach)
            img_cached = img
                .acquire_prefix(id, &ch.img, img_need * IMG_BLOCK)
                .expect("fresh table")
                .min(r.spec.image_tokens());
        }
        img.grow(id, img_need * IMG_BLOCK).expect("can_admit checked image capacity");
    }
    (kv_cached, img_cached)
}

/// Run the simulation over a request trace.
pub fn simulate(cfg: &SimConfig, requests: &[RequestSpec]) -> SimResult {
    let masks = cfg.cluster.instance_masks();
    let profile = BudgetProfile::default();
    let token_budget = compute_token_budget(&cfg.model, &cfg.device, &profile, cfg.slo.tpot).max(64);
    let image_budget = compute_image_budget(&cfg.model, &cfg.device, &profile, cfg.slo.tpot).max(1);
    let budgets = Budgets { token_budget, image_budget, max_decode_batch: 512 };

    // cluster-wide content directory (fetch-over-recompute) — requires the
    // content cache; off reproduces per-instance affinity bit-for-bit
    let dirs = (cfg.content_cache && cfg.cache_directory).then(|| DirState {
        kv: ContentDirectory::new(masks.len()),
        img: ContentDirectory::new(masks.len()),
        report: DirectoryReport::default(),
    });

    let mut instances = build_instances(cfg, &masks, dirs.is_some());

    let mut state = EngineState {
        cfg,
        budgets,
        router: Router::new(RoutePolicy::LeastLoaded, cfg.seed),
        tracker: DrainTracker::new(instances.len()),
        dirs,
        heap: BinaryHeap::new(),
        seq: 0,
        events: 0,
        migrations: 0,
        batches: 0,
        dropped: 0,
        report: CacheReport::default(),
        lifecycles: FxHashMap::default(),
        ready_since: FxHashMap::default(),
        chains: FxHashMap::default(),
        no_chains: Arc::new(HashChains::empty()),
        scratch: Scratch::default(),
        tracer: if cfg.trace {
            Tracer::with_capacity(cfg.trace_capacity)
        } else {
            Tracer::off()
        },
    };

    for (i, r) in requests.iter().enumerate() {
        state.push(r.arrival, EvKind::Arrival(i));
    }

    // elastic control plane (estimator -> policy -> drain tracker)
    let mut controller = cfg.controller.as_ref().map(|cc| {
        let rates = StageRates::from_model(&cfg.model, &cfg.device);
        (
            cc.clone(),
            StageLoadEstimator::new(cc.clone(), rates, Some(cfg.slo)),
            ReconfigPolicy::new(cc.clone()),
        )
    });
    if let Some((cc, _, _)) = &controller {
        state.push(cc.tick, EvKind::ControllerTick);
    }

    while let Some(ev) = state.heap.pop() {
        let now = ev.t;
        if now > cfg.horizon {
            break;
        }
        state.events += 1;
        match ev.kind {
            EvKind::Arrival(i) => {
                let spec = requests[i].clone();
                // route by request type (paper §4): first needed stage
                let first = spec.first_stage();
                state.scratch.candidates.clear();
                for inst in instances.iter() {
                    if inst.mask.serves(first) {
                        state.scratch.candidates.push(inst.id);
                    }
                }
                // content identity is derived exactly once, here (the
                // hash-once rule); every later touchpoint borrows `ch`
                let ch = if cfg.content_cache {
                    Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK))
                } else {
                    state.no_chains.clone()
                };
                // cache affinity: prefer the candidate already holding
                // this request's image embedding / KV prefix. With the
                // directory, one sweep over the hash chain answers for
                // every candidate at once; without it, each candidate's
                // private index is scanned (PR 2 behaviour).
                build_affinity(&instances, &mut state, &ch, true);
                let Some(target) = route_among_affinity(&instances, &mut state) else {
                    // no instance can serve this request type: count the
                    // drop explicitly and leave no half-initialized state
                    // behind (a stale Lifecycle + ready_since entry used
                    // to leak here)
                    state.dropped += 1;
                    crate::log_trace!("t={now:.6} drop req={} (no instance serves {first:?})", spec.id.0);
                    state.tracer.span(
                        SpanKind::Drop,
                        crate::obs::trace::NO_INSTANCE as usize,
                        spec.id.0,
                        now,
                        now,
                        0,
                    );
                    continue;
                };
                let rid = spec.id;
                crate::log_trace!("t={now:.6} arrival req={} -> inst{target}", rid.0);
                state.lifecycles.insert(rid.0, Lifecycle::new(spec.arrival));
                state.ready_since.insert(rid.0, now);
                if cfg.content_cache {
                    state.chains.insert(rid.0, ch.clone());
                }
                let mut st = ReqState::new(spec);
                if cfg.content_cache {
                    instances[target].attach(&mut st, &ch.kv, &ch.img, &mut state.report);
                }
                // fetch-over-recompute: the routed target lacks content a
                // peer advertises, and pulling it is priced below
                // recomputing — park the request until the transfer lands
                if state.dirs.is_some() {
                    match maybe_start_fetch(&mut instances, target, st, &ch, now, &mut state) {
                        None => continue, // parked; FetchDone resumes it
                        Some(back) => st = back,
                    }
                }
                let stage = st.stage();
                if instances[target].mask.serves(stage) {
                    instances[target].queues.push_waiting(st);
                } else {
                    // cache hits advanced the request past every stage this
                    // instance serves (e.g. a cached image on an E-only
                    // node): admit it and hand it straight to the owner of
                    // its next stage
                    instances[target].queues.push_running(st);
                    start_migration(&mut instances, target, rid, stage, now, &mut state);
                    // no batch completion will wake the target on an
                    // otherwise-idle cluster: admit the pull now
                    process_inboxes(&mut instances, now, &mut state);
                    for i in 0..instances.len() {
                        try_start(&mut instances, i, now, &mut state);
                    }
                }
                try_start(&mut instances, target, now, &mut state);
            }

            EvKind::BatchDone(iid) => {
                let (batch, started) = instances[iid]
                    .current
                    .take()
                    .expect("BatchDone for idle instance");
                let dur = now - started;
                crate::log_trace!(
                    "t={now:.6} batch done inst{iid} items={} dur={dur:.6}",
                    batch.items.len()
                );
                apply_batch(&mut instances, iid, &batch, started, dur, now, &mut state);
                // wake everyone: migrations may have unblocked peers
                process_inboxes(&mut instances, now, &mut state);
                for i in 0..instances.len() {
                    try_start(&mut instances, i, now, &mut state);
                }
            }

            EvKind::TransferDone { src, dst, req } => {
                // step 4: target holds the data; source releases resources
                instances[src].queues.remove_running(req);
                instances[src].release_all(req);
                if let Some(pull) = instances[dst].incoming.remove(&req.0) {
                    let mut r = pull.req;
                    r.migrating = false;
                    if pull.kv_cached > 0 {
                        // prefill resumes at the prefix the target held
                        r.cached_prefill = r.cached_prefill.max(pull.kv_cached);
                        r.prefilled = r.prefilled.max(pull.kv_cached);
                    }
                    // the target now holds this content: publish it
                    if cfg.content_cache {
                        let ch = state.chains_for(&r.spec);
                        match pull.phase {
                            Phase::EpMigration => {
                                if r.spec.image_hash.is_some() {
                                    let new = instances[dst].img.commit_hashes(req, &ch.img);
                                    if let Some(d) = state.dirs.as_mut() {
                                        d.img.publish(dst, &new);
                                    }
                                }
                            }
                            _ => {
                                let new =
                                    instances[dst].kv.commit_hashes(req, ch.kv_commit());
                                if let Some(d) = state.dirs.as_mut() {
                                    d.kv.publish(dst, &new);
                                }
                            }
                        }
                    }
                    if let Some(lc) = state.lifecycles.get_mut(&req.0) {
                        lc.add_phase(pull.phase, now - pull.created);
                    }
                    state.tracer.span(
                        SpanKind::from_phase(pull.phase),
                        dst,
                        req.0,
                        pull.created,
                        now,
                        pull.kv_cached as u64,
                    );
                    state.ready_since.insert(req.0, now);
                    crate::log_trace!("t={now:.6} transfer done req={} inst{src}->inst{dst}", req.0);
                    instances[dst].queues.push_running(r);
                }
                process_inboxes(&mut instances, now, &mut state);
                for i in 0..instances.len() {
                    try_start(&mut instances, i, now, &mut state);
                }
            }

            EvKind::FetchDone { dst, req } => {
                crate::log_trace!("t={now:.6} fetch landed req={} at inst{dst}", req.0);
                handle_fetch_done(&mut instances, dst, req, now, &mut state);
                process_inboxes(&mut instances, now, &mut state);
                for i in 0..instances.len() {
                    try_start(&mut instances, i, now, &mut state);
                }
            }

            EvKind::ControllerTick => {
                // (1) a completed flip elsewhere may have orphaned a
                // hand-off attempt: re-offer stranded requests first
                retry_stranded(&mut instances, now, &mut state);
                let Some((cc, est, pol)) = controller.as_mut() else { continue };

                // (2) observe queue depths + windowed latency tails
                let w = crate::metrics::window_stats(state.lifecycles.values(), now - cc.window);
                est.observe(cluster_sample(&instances, &state.tracker, now, &w));

                // (3) decide: at most one new drain per tick
                if let Some(load) = est.snapshot() {
                    let masks: Vec<StageMask> = instances.iter().map(|i| i.mask).collect();
                    let draining = state.tracker.draining_flags();
                    if let Some(d) = pol.decide(now, &load, &masks, &draining) {
                        state.tracker.begin(now, d.instance, d.to);
                    }
                }

                // (4) progress drains: cancel expired ones, flip emptied ones
                for iid in 0..instances.len() {
                    if !state.tracker.is_draining(iid) {
                        continue;
                    }
                    if state.tracker.expired(now, iid, cc.drain_timeout) {
                        state.tracker.cancel(iid);
                        continue;
                    }
                    let inst = &instances[iid];
                    let empty = inst.current.is_none()
                        && inst.queues.total() == 0
                        && inst.inbox.is_empty()
                        && inst.incoming.is_empty()
                        && inst.fetching.is_empty();
                    if empty {
                        let to = state.tracker.complete(now, iid, inst.mask);
                        crate::log_trace!("t={now:.6} role flip inst{iid} -> {}", to.label());
                        state.tracer.mark(SpanKind::RoleFlip, iid, now, mask_bits(to));
                        let (kv_blocks, img_blocks) = cache_blocks(&cfg.model, &cfg.device, to);
                        let inst = &mut instances[iid];
                        inst.mask = to;
                        inst.sched = cfg.policy.make(to);
                        // the instance is empty: re-partition its HBM for
                        // the new role's cache mix (cached content is
                        // dropped — bank the old caches' counters first,
                        // and retract every advertisement wholesale)
                        state.report.kv_stats.merge(&inst.kv.stats());
                        state.report.img_stats.merge(&inst.img.stats());
                        inst.kv = PagedCache::new(kv_blocks, KV_BLOCK, 1024);
                        inst.img =
                            PagedCache::new(img_blocks, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
                        if let Some(d) = state.dirs.as_mut() {
                            d.kv.retract_all(iid);
                            d.img.retract_all(iid);
                            inst.kv.set_eviction_tracking(true);
                            inst.img.set_eviction_tracking(true);
                        }
                    }
                }

                // (5) wake the cluster (retries may have queued pulls)
                process_inboxes(&mut instances, now, &mut state);
                for i in 0..instances.len() {
                    try_start(&mut instances, i, now, &mut state);
                }

                // (6) keep ticking while the run is live
                let live = state.lifecycles.len() < requests.len()
                    || state.lifecycles.values().any(|lc| lc.finished_at.is_none())
                    || state.tracker.any_draining();
                if live && now + cc.tick <= cfg.horizon {
                    state.push(now + cc.tick, EvKind::ControllerTick);
                }
            }
        }
    }

    // collect metrics
    let EngineState {
        tracker,
        dirs,
        events,
        migrations,
        batches,
        dropped,
        mut report,
        lifecycles,
        mut tracer,
        ..
    } = state;
    let mut metrics = RunMetrics::default();
    let mut unfinished = 0;
    for (id, lc) in lifecycles {
        if lc.finished_at.is_none() {
            unfinished += 1;
        }
        metrics.insert(RequestId(id), lc);
    }
    for inst in &instances {
        report.kv_stats.merge(&inst.kv.stats());
        report.img_stats.merge(&inst.img.stats());
    }
    if let Some(d) = dirs {
        let mut dr = d.report;
        dr.queries = d.kv.stats().queries + d.img.stats().queries;
        dr.publishes = d.kv.stats().publishes + d.img.stats().publishes;
        dr.retractions = d.kv.stats().retractions + d.img.stats().retractions;
        report.directory = dr;
    }
    let trace_dropped = tracer.dropped();
    SimResult {
        metrics,
        migrations,
        batches,
        events,
        unfinished,
        dropped_requests: dropped,
        reconfigs: tracker.num_reconfigs(),
        reconfig_events: tracker.events,
        cache: report,
        trace: tracer.take_spans(),
        trace_dropped,
    }
}

/// Build the per-instance state for a cluster layout (shared by
/// [`simulate`] and the engine's unit tests, which drive event handlers
/// directly against the same instances the production loop uses).
fn build_instances(cfg: &SimConfig, masks: &[StageMask], track_evictions: bool) -> Vec<SimInstance> {
    masks
        .iter()
        .enumerate()
        .map(|(id, &mask)| {
            let (kv_blocks, img_blocks) = cache_blocks(&cfg.model, &cfg.device, mask);
            let mut kv = PagedCache::new(kv_blocks, KV_BLOCK, 1024);
            let mut img =
                PagedCache::new(img_blocks, IMG_BLOCK, 64).with_cost_class(COST_IMAGE);
            if track_evictions {
                kv.set_eviction_tracking(true);
                img.set_eviction_tracking(true);
            }
            SimInstance {
                id,
                mask,
                sched: cfg.policy.make(mask),
                queues: Queues::default(),
                kv,
                img,
                current: None,
                inbox: Vec::new(),
                incoming: FxHashMap::default(),
                fetching: FxHashMap::default(),
            }
        })
        .collect()
}

/// Fill `scratch.affinity` (parallel to `scratch.candidates`) with each
/// candidate's cache-affinity score for the memoized chains `ch`.
/// `with_img` gates the image plane (migration targeting for a PD hop
/// only scores the KV plane, matching the payload it would ship).
///
/// With the directory: one sweep per plane answers every candidate.
/// Directory off (content cache still on): per-candidate private-index
/// scans with a **pick-preserving early-exit**. Once some candidate holds
/// the full chain and is routable (not draining, load within
/// [`Router::affinity_load_cap`]), it wins `pick_affinity` outright —
/// maximum possible affinity, ties broken toward lower load — so the
/// only later candidates that could still displace it are routable ones
/// at *strictly lower* load (they might also hold the full chain). Only
/// those are scanned; everything else is skipped with affinity 0, which
/// cannot change the outcome because a full-affinity candidate is
/// already on the board. Routing decisions are bit-identical to the old
/// scan-everything code.
fn build_affinity(
    instances: &[SimInstance],
    state: &mut EngineState,
    ch: &HashChains,
    with_img: bool,
) {
    let cfg = state.cfg;
    state.scratch.affinity.clear();
    if let Some(d) = state.dirs.as_mut() {
        d.kv.prefix_blocks_into(&ch.kv, &mut state.scratch.kv_pfx);
        if with_img {
            d.img.prefix_blocks_into(&ch.img, &mut state.scratch.img_pfx);
        } else {
            state.scratch.img_pfx.clear();
            state.scratch.img_pfx.resize(instances.len(), 0);
        }
        for &c in &state.scratch.candidates {
            state.scratch.affinity.push(
                (state.scratch.kv_pfx[c] * KV_BLOCK + state.scratch.img_pfx[c] * IMG_BLOCK)
                    as f64,
            );
        }
    } else if cfg.content_cache {
        let full_img = if with_img { ch.img.len() * IMG_BLOCK } else { 0 };
        let full = (ch.kv.len() * KV_BLOCK + full_img) as f64;
        // the same eligibility rule pick_affinity applies, precomputed so
        // the early-exit can never hide a holder the pick would still need
        let mut min_load = f64::INFINITY;
        for &c in &state.scratch.candidates {
            if !state.tracker.is_draining(c) {
                min_load = min_load.min(instances[c].load());
            }
        }
        let cap = Router::affinity_load_cap(min_load);
        // load of the winning routable full holder found so far
        let mut winner_load: Option<f64> = None;
        for &c in &state.scratch.candidates {
            let load = instances[c].load();
            let routable = !state.tracker.is_draining(c) && load <= cap;
            if let Some(wl) = winner_load {
                if !routable || load >= wl {
                    // cannot displace the current full holder: skip the
                    // scan (a zero here never changes the pick — a
                    // full-affinity candidate is already on the board,
                    // and on equal load the earlier candidate wins the
                    // tie anyway)
                    state.scratch.affinity.push(0.0);
                    continue;
                }
            }
            let mut a = instances[c].kv.lookup_prefix(&ch.kv) * KV_BLOCK;
            if with_img {
                a += instances[c].img.lookup_prefix(&ch.img) * IMG_BLOCK;
            }
            let a = a as f64;
            state.scratch.affinity.push(a);
            if a >= full && full > 0.0 && routable {
                winner_load = Some(load);
            }
        }
    } else {
        state.scratch.affinity.resize(state.scratch.candidates.len(), 0.0);
    }
}

/// Decide whether the freshly routed request should **fetch** content a
/// peer advertises instead of recomputing it (the §4.5 reuse extension,
/// taken cluster-wide): the image-embedding and KV-prefix parts are priced
/// independently against the cost model (encode vs. transfer bytes;
/// prefill of the missing prefix vs. its KV bytes) and only taken when the
/// link is cheaper. On a fetch, blocks are reserved now, the request parks
/// in `fetching`, and one `FetchDone` event carries both parts. Returns
/// the request back when nothing is worth fetching (including when the
/// directory is off).
fn maybe_start_fetch(
    instances: &mut [SimInstance],
    target: usize,
    st: ReqState,
    ch: &HashChains,
    now: f64,
    state: &mut EngineState,
) -> Option<ReqState> {
    let cfg = state.cfg;
    let Some(dirs) = state.dirs.as_mut() else { return Some(st) };
    let (link_lat, link_bw) = cfg.link();
    let id = st.spec.id;
    let mut img_src = None;
    let mut kv_src = None;
    let mut bytes = 0.0f64;

    // image embedding part (pricing + holder in the shared helper; the
    // capacity check is planning-time only — a redirect re-plans with the
    // blocks already reserved)
    if let Some((src, fetch_bytes)) = img_fetch_source(instances, dirs, cfg, target, &st, ch) {
        let needed = img_blocks_for(st.spec.image_tokens());
        let img_need = needed.saturating_sub(instances[target].img.held_blocks(id));
        if instances[target].img_blocks_needed(&st) > 0
            && img_need <= instances[target].img.available_blocks()
        {
            img_src = Some(src);
            bytes += fetch_bytes;
        }
    }

    // KV-prefix part
    if instances[target].kv_tokens_needed(&st) > 0 {
        if let Some((src, to_tokens, fetch_bytes)) =
            kv_fetch_source(instances, dirs, cfg, target, &st, ch)
        {
            let kv_need = kv_blocks_for(to_tokens)
                .saturating_sub(instances[target].kv.held_blocks(id));
            if kv_need <= instances[target].kv.available_blocks() {
                kv_src = Some((src, to_tokens));
                bytes += fetch_bytes;
            }
        }
    }

    if img_src.is_none() && kv_src.is_none() {
        return Some(st);
    }

    // reserve the blocks now (they are needed either way), park the
    // request, and schedule the landing
    let inst = &mut instances[target];
    if img_src.is_some() {
        let need = img_blocks_for(st.spec.image_tokens());
        inst.img
            .grow(id, need * IMG_BLOCK)
            .expect("capacity checked for image fetch");
    }
    if let Some((_, to_tokens)) = kv_src {
        inst.kv.grow(id, to_tokens).expect("capacity checked for kv fetch");
    }
    dirs.sync_evictions(inst);
    dirs.report.fetches += 1;
    let dur = link_lat + bytes / link_bw;
    state.push(now + dur, EvKind::FetchDone { dst: target, req: id });
    state.tracer.span(SpanKind::Fetch, target, id.0, now, now + dur, bytes as u64);
    instances[target].fetching.insert(
        id.0,
        PendingFetch { req: st, img_src, kv_src, redirected: false, stale_counted: false },
    );
    None
}

/// The image-embedding part of a fetch plan: the best current holder of
/// the WHOLE embedding (among maximal holders, the least-loaded — a hot
/// holder should not also serve every fetch), when pulling it is priced
/// below re-encoding. Returns `(source, payload bytes)`. Pricing and
/// holder choice only — capacity is the caller's concern (checked when
/// first planning; already reserved when a landing re-validates).
fn img_fetch_source(
    instances: &[SimInstance],
    dirs: &mut DirState,
    cfg: &SimConfig,
    target: usize,
    st: &ReqState,
    ch: &HashChains,
) -> Option<(usize, f64)> {
    // only whole-embedding hits are useful (encode runs per image; a
    // partial block set cannot shorten it)
    if st.encoded_images >= st.spec.num_images || st.spec.image_hash.is_none() {
        return None;
    }
    let needed = img_blocks_for(st.spec.image_tokens());
    let (src, blocks) = dirs.img.best_holder_by(&ch.img, target, |i| instances[i].load())?;
    if blocks < needed {
        return None;
    }
    let (link_lat, link_bw) = cfg.link();
    let remaining = st.spec.num_images - st.encoded_images;
    let miss_tokens = remaining * st.spec.tokens_per_image;
    let fetch_bytes = crate::costmodel::ops::image_payload_bytes(&cfg.model, miss_tokens);
    let fetch_t = link_lat + fetch_bytes / link_bw;
    let recompute_t =
        exec_time(encode_cost(&cfg.model, remaining), &cfg.device) + cfg.engine_overhead;
    (fetch_t < recompute_t).then_some((src, fetch_bytes))
}

/// The KV-prefix part of a fetch plan: fetch only the delta past what the
/// local cache already served, block-aligned and leaving >= 1 token for
/// prefill to emit from. Recompute is priced as a *resumed* prefill of
/// the missing delta ([`prefill_resume_cost`]) — the real plane now
/// executes exactly that op, so the fetch decision and the compute it
/// replaces stay in the same currency. Returns
/// `(source, prefix tokens fetched to, payload bytes)`.
fn kv_fetch_source(
    instances: &[SimInstance],
    dirs: &mut DirState,
    cfg: &SimConfig,
    target: usize,
    st: &ReqState,
    ch: &HashChains,
) -> Option<(usize, usize, f64)> {
    if st.prefill_remaining() == 0 {
        return None;
    }
    let cap_blocks = st.spec.prefill_tokens().saturating_sub(1) / KV_BLOCK;
    let (src, blocks) = dirs.kv.best_holder_by(&ch.kv, target, |i| instances[i].load())?;
    let to_tokens = blocks.min(cap_blocks) * KV_BLOCK;
    if to_tokens <= st.prefilled {
        return None;
    }
    let delta = to_tokens - st.prefilled;
    let (link_lat, link_bw) = cfg.link();
    let fetch_bytes =
        crate::costmodel::ops::kv_delta_payload_bytes(&cfg.model, to_tokens, st.prefilled);
    let fetch_t = link_lat + fetch_bytes / link_bw;
    let recompute_t =
        exec_time(prefill_resume_cost(&cfg.model, st.prefilled, delta), &cfg.device)
            + cfg.engine_overhead;
    (fetch_t < recompute_t).then_some((src, to_tokens, fetch_bytes))
}

/// Apply a landed cache fetch. The plan was decided when the request
/// arrived; by landing/service time the advertised holder may have
/// evicted the content (the arrival→service staleness window). Each part
/// is validated against the source's **actual** cache; a part that went
/// stale is re-validated against the **current** directory and redirected
/// to a surviving holder (one redirect per fetch — a second stale landing
/// means the directory is churning), and only when no priced-worthwhile
/// holder remains does the request fall back to recomputing that part,
/// counted in `stale_fetches`. Parts that landed keep their credit either
/// way.
fn handle_fetch_done(
    instances: &mut [SimInstance],
    dst: usize,
    req: RequestId,
    now: f64,
    state: &mut EngineState,
) {
    let Some(mut f) = instances[dst].fetching.remove(&req.0) else { return };
    let ch = state.chains_for(&f.req.spec);
    let cfg = state.cfg;
    let (link_lat, link_bw) = cfg.link();
    let mut any_stale = false;
    let mut retry = false;
    let mut retry_bytes = 0.0f64;
    {
        let dirs = state.dirs.as_mut().expect("fetches require the directory");
        // image part: validate against the source's actual cache — an
        // eviction mid-flight makes the advertisement stale
        if let Some(src) = f.img_src.take() {
            let needed = img_blocks_for(f.req.spec.image_tokens());
            if instances[src].img.lookup_prefix(&ch.img) >= needed {
                let fetched = f.req.spec.num_images - f.req.encoded_images;
                let new = instances[dst].img.commit_hashes(req, &ch.img);
                dirs.img.publish(dst, &new);
                f.req.cached_images = f.req.spec.num_images;
                f.req.encoded_images = f.req.spec.num_images;
                dirs.report.fetched_images += fetched;
            } else if !f.redirected {
                // stale: re-validate against the current directory (the
                // blocks are already reserved locally, so only holder +
                // pricing are re-checked)
                match img_fetch_source(instances, dirs, cfg, dst, &f.req, &ch) {
                    Some((src2, bytes)) => {
                        f.img_src = Some(src2);
                        retry_bytes += bytes;
                        retry = true;
                    }
                    None => any_stale = true,
                }
            } else {
                any_stale = true;
            }
        }
        // KV-prefix part
        if let Some((src, to_tokens)) = f.kv_src.take() {
            let blocks = to_tokens / KV_BLOCK;
            if instances[src].kv.lookup_prefix(&ch.kv[..blocks]) >= blocks {
                let new = instances[dst].kv.commit_hashes(req, &ch.kv[..blocks]);
                dirs.kv.publish(dst, &new);
                dirs.report.fetched_kv_tokens += to_tokens.saturating_sub(f.req.prefilled);
                f.req.cached_prefill = f.req.cached_prefill.max(to_tokens);
                f.req.prefilled = f.req.prefilled.max(to_tokens);
            } else if !f.redirected {
                match kv_fetch_source(instances, dirs, cfg, dst, &f.req, &ch) {
                    Some((src2, to2, bytes)) => {
                        f.kv_src = Some((src2, to2));
                        retry_bytes += bytes;
                        retry = true;
                    }
                    None => any_stale = true,
                }
            } else {
                any_stale = true;
            }
        }
        if retry {
            dirs.report.redirected_fetches += 1;
        }
        // a FETCH counts stale at most once, mirroring `fetches` (one
        // combined transfer per request) — even when its parts are
        // abandoned across different landings (e.g. img part gives up on
        // landing 1 while the kv part redirects and fails on landing 2)
        if any_stale && !f.stale_counted {
            dirs.report.stale_fetches += 1;
            f.stale_counted = true;
        }
    }
    if retry {
        f.redirected = true;
        let dur = link_lat + retry_bytes / link_bw;
        state.push(now + dur, EvKind::FetchDone { dst, req });
        state.tracer.span(SpanKind::Fetch, dst, req.0, now, now + dur, retry_bytes as u64);
        instances[dst].fetching.insert(req.0, f);
        return;
    }
    // resume the normal dispatch path with whatever credit landed
    let r = f.req;
    let stage = r.stage();
    if instances[dst].mask.serves(stage) {
        instances[dst].queues.push_waiting(r);
    } else {
        instances[dst].queues.push_running(r);
        start_migration(instances, dst, req, stage, now, state);
    }
}

/// Route among `scratch.candidates` (affinity scores already built by
/// [`build_affinity`] in `scratch.affinity`), treating mid-drain
/// instances as ineligible (infinite load) and preferring cache affinity
/// (reusable tokens already on each candidate): a candidate holding
/// cached content wins over a merely idle one; zero affinity everywhere
/// degrades to the plain load policy. If *every* candidate is mid-drain,
/// fall back to their raw loads: work is never dropped just because
/// flips are in flight.
fn route_among_affinity(instances: &[SimInstance], state: &mut EngineState) -> Option<usize> {
    if state.scratch.candidates.is_empty() {
        return None;
    }
    state.scratch.gated.clear();
    for &i in &state.scratch.candidates {
        state.scratch.gated.push(if state.tracker.is_draining(i) {
            f64::INFINITY
        } else {
            instances[i].load()
        });
    }
    if let Some(p) = state.router.pick_affinity(&state.scratch.gated, &state.scratch.affinity) {
        return Some(state.scratch.candidates[p]);
    }
    state.scratch.gated.clear();
    for &i in &state.scratch.candidates {
        state.scratch.gated.push(instances[i].load());
    }
    state.router.pick(&state.scratch.gated).map(|p| state.scratch.candidates[p])
}

/// One controller-tick observation: per-instance backlogs by next stage
/// (queues + in-flight pulls) plus the windowed latency tails.
fn cluster_sample(
    instances: &[SimInstance],
    tracker: &DrainTracker,
    now: f64,
    w: &crate::metrics::WindowStats,
) -> ClusterSample {
    let mut out = ClusterSample {
        t: now,
        instances: Vec::with_capacity(instances.len()),
        ttft_p90: w.ttft_p90(),
        tpot_p90: w.tpot_p90(),
    };
    for inst in instances {
        let mut s = InstanceSample::idle(inst.mask, tracker.is_draining(inst.id));
        s.batch_items = inst.current.as_ref().map_or(0, |(b, _)| b.items.len());
        // skip migrating requests at the source: the in-flight copy in the
        // target's inbox/incoming already carries their backlog
        for r in inst
            .queues
            .iter_waiting()
            .chain(inst.queues.running().iter().filter(|r| !r.migrating))
        {
            s.add_req(r);
        }
        for p in inst.inbox.iter().chain(inst.incoming.values()) {
            s.add_req(&p.req);
        }
        for f in inst.fetching.values() {
            s.add_req(&f.req);
        }
        out.instances.push(s);
    }
    out
}

/// Re-offer running requests whose next stage their host no longer serves
/// and that own no in-flight migration — a role flip (or an earlier
/// failed hand-off) can orphan them, and nothing else retries.
fn retry_stranded(instances: &mut [SimInstance], now: f64, state: &mut EngineState) {
    for iid in 0..instances.len() {
        let mask = instances[iid].mask;
        let stranded: Vec<(RequestId, Stage)> = instances[iid]
            .queues
            .running()
            .iter()
            .filter(|r| !r.migrating && !mask.serves(r.stage()))
            .map(|r| (r.spec.id, r.stage()))
            .collect();
        for (id, stage) in stranded {
            start_migration(instances, iid, id, stage, now, state);
        }
    }
}

/// §4.3 step 1 for one request: snapshot it, pick a pull target for its
/// next stage, and enqueue the offer in the target's inbox.
fn start_migration(
    instances: &mut [SimInstance],
    iid: usize,
    id: RequestId,
    next_stage: Stage,
    now: f64,
    state: &mut EngineState,
) {
    let Some(r) = instances[iid].queues.find_running(id) else { return };
    r.migrating = true;
    let snapshot = r.clone();
    let phase = match next_stage {
        Stage::Prefill => Phase::EpMigration,
        _ => Phase::PdMigration,
    };
    let payload_tokens = match next_stage {
        // EP migration carries the image-token embeddings
        Stage::Prefill => snapshot.spec.image_tokens(),
        // PD migration carries the prefix KV cache
        _ => snapshot.spec.prefill_tokens(),
    };
    state.scratch.candidates.clear();
    for inst in instances.iter() {
        if inst.id != iid && inst.mask.serves(next_stage) {
            state.scratch.candidates.push(inst.id);
        }
    }
    // cache affinity: a target already holding the payload's blocks needs
    // (almost) nothing transferred. The directory answers for every
    // candidate in one sweep; without it each private index is scanned.
    let ch = state.chains_for(&snapshot.spec);
    build_affinity(instances, state, &ch, next_stage == Stage::Prefill);
    if let Some(dst) = route_among_affinity(instances, state) {
        state.migrations += 1;
        instances[dst].inbox.push(PendingPull {
            req: snapshot,
            src: iid,
            phase,
            payload_tokens,
            kv_cached: 0,
            created: now,
        });
    } else if let Some(r) = instances[iid].queues.find_running(id) {
        // nowhere to go (incomplete cluster): request is stuck; it will
        // count as unfinished. Un-mark so we don't spin.
        r.migrating = false;
    }
}

/// Batch duration from the cost model: the LM stream (prefill chunks +
/// decode tokens, genuinely fused kernels) and the vision stream (encode),
/// combined per the multi-stream setting.
fn batch_duration(batch: &Batch, cfg: &SimConfig) -> f64 {
    let mut chunks: Vec<(usize, usize)> = Vec::new();
    let mut dctx: Vec<usize> = Vec::new();
    let mut imgs = 0usize;
    for (_, w) in &batch.items {
        match w {
            TaskWork::PrefillChunk { ctx, tokens } => chunks.push((*ctx, *tokens)),
            TaskWork::DecodeToken { ctx } => dctx.push(*ctx),
            TaskWork::Encode { images } => imgs += images,
            TaskWork::Migrate => {}
        }
    }
    // fused LM iteration: weights read once across prefill chunks + decodes
    let lm: Cost = iteration_cost(&cfg.model, &chunks, &dctx);
    let vis: Cost = encode_cost(&cfg.model, imgs);
    let mut streams: Vec<Cost> = Vec::new();
    if lm.flops > 0.0 {
        streams.push(lm);
    }
    if vis.flops > 0.0 {
        streams.push(vis);
    }
    if streams.is_empty() {
        return 0.0;
    }
    let kernel_time = if cfg.multistream {
        parallel_time(&streams, &cfg.device)
    } else {
        sequential_time(&streams, &cfg.device)
    };
    kernel_time + cfg.engine_overhead
}

fn try_start(instances: &mut [SimInstance], iid: usize, now: f64, state: &mut EngineState) {
    if instances[iid].current.is_some() {
        return;
    }
    let cfg = state.cfg;
    // split-borrow: scheduler + queues + capacity checks live on the same
    // instance; temporarily move the scheduler out.
    let inst = &mut instances[iid];
    let mut sched = std::mem::replace(&mut inst.sched, Box::new(NullSched));
    let batch = {
        let kv = &inst.kv;
        let img = &inst.img;
        let mask = inst.mask;
        let kv_avail = kv.available_blocks();
        let img_avail = img.available_blocks();
        let mut kv_used = 0usize;
        let mut img_used = 0usize;
        let mut admit = |r: &ReqState| -> bool {
            // blocks already pinned (cached prefix) cost nothing; evictable
            // cached blocks count as capacity — backpressure only when
            // genuinely full
            let kv_need = kv_blocks_for(kv_tokens_needed_mask(mask, r))
                .saturating_sub(kv.held_blocks(r.spec.id));
            let img_need =
                img_blocks_needed_mask(mask, r).saturating_sub(img.held_blocks(r.spec.id));
            if kv_used + kv_need <= kv_avail && img_used + img_need <= img_avail {
                kv_used += kv_need;
                img_used += img_need;
                true
            } else {
                false
            }
        };
        sched.build_batch(&mut inst.queues, &state.budgets, &mut admit)
    };
    inst.sched = sched;

    // reserve blocks for any running request not yet fully allocated.
    // Skip requests that are migrating away or whose next stage we don't
    // serve (the cache-hit bounce path admits those without a capacity
    // check — they keep only their pinned prefix until the pull lands).
    // Split borrow (queues shared / caches mut) so nothing is cloned.
    {
        let SimInstance { queues, kv, img, mask, .. } = &mut instances[iid];
        let mask = *mask;
        for r in queues.running() {
            if r.migrating || !mask.serves(r.stage()) {
                continue;
            }
            let ch =
                chains_entry(&mut state.chains, cfg.content_cache, &state.no_chains, &r.spec);
            reserve_blocks(mask, kv, img, r, &ch);
        }
    }
    // reserving may have evicted cached blocks: retract them from the
    // cluster directory before anyone queries it again
    if let Some(d) = state.dirs.as_mut() {
        d.sync_evictions(&mut instances[iid]);
    }

    let has_compute = batch
        .items
        .iter()
        .any(|(_, w)| !matches!(w, TaskWork::Migrate));
    if !has_compute {
        return;
    }
    let dur = batch_duration(&batch, cfg);
    state.batches += 1;
    instances[iid].current = Some((batch, now));
    state.push(now + dur, EvKind::BatchDone(iid));
}

fn kv_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    if !(mask.prefill || mask.decode) {
        return 0;
    }
    r.spec.prefill_tokens() + if mask.decode { r.spec.output_tokens } else { 0 }
}

fn img_blocks_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    let consumes = mask.encode || (mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
    if consumes {
        img_blocks_for(r.spec.image_tokens())
    } else {
        0
    }
}

/// Apply a completed batch: advance request progress, record tokens,
/// trigger migrations, finish requests.
fn apply_batch(
    instances: &mut [SimInstance],
    iid: usize,
    batch: &Batch,
    started: f64,
    dur: f64,
    now: f64,
    state: &mut EngineState,
) {
    let cfg = state.cfg;
    // take the scratch accumulators so later helper calls can borrow
    // `state` mutably (returned below — allocation-free after warmup)
    let mut to_finish = std::mem::take(&mut state.scratch.to_finish);
    let mut to_migrate = std::mem::take(&mut state.scratch.to_migrate);
    to_finish.clear();
    to_migrate.clear();

    for (id, work) in &batch.items {
        let mask = instances[iid].mask;
        let Some(r) = instances[iid].queues.find_running(*id) else {
            continue; // migrated away mid-flight (migrate items)
        };
        let lc = state.lifecycles.get_mut(&id.0).expect("lifecycle exists");
        // single map access per item: read the ready timestamp and write
        // the new one through the same entry (always present — inserted
        // at arrival, removed only at finish)
        let rs_slot = state.ready_since.entry(id.0).or_insert(started);
        let rs = *rs_slot;
        match work {
            TaskWork::Encode { images } => {
                r.encoded_images += images;
                lc.add_phase(Phase::EncodeQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::EncodeExec, dur);
                *rs_slot = now;
                state.tracer.span(SpanKind::EncodeQueue, iid, id.0, rs.min(started), started, 0);
                state.tracer.span(SpanKind::EncodeExec, iid, id.0, started, now, *images as u64);
                if r.encode_remaining() == 0 {
                    let rid = *id;
                    // publish the finished embedding for cross-request reuse
                    if cfg.content_cache && r.spec.image_hash.is_some() {
                        let ch = chains_entry(
                            &mut state.chains,
                            cfg.content_cache,
                            &state.no_chains,
                            &r.spec,
                        );
                        let new = instances[iid].img.commit_hashes(rid, &ch.img);
                        if let Some(d) = state.dirs.as_mut() {
                            d.img.publish(iid, &new);
                        }
                    }
                    if !mask.prefill {
                        to_migrate.push((rid, Stage::Prefill));
                    }
                }
            }
            TaskWork::PrefillChunk { tokens, .. } => {
                r.prefilled += tokens;
                lc.add_phase(Phase::PrefillQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::PrefillExec, dur);
                *rs_slot = now;
                state.tracer.span(SpanKind::PrefillQueue, iid, id.0, rs.min(started), started, 0);
                state.tracer.span(SpanKind::PrefillExec, iid, id.0, started, now, *tokens as u64);
                if r.prefill_remaining() == 0 {
                    // prefill emits the first output token
                    r.decoded = 1;
                    lc.record_token(now);
                    let rid = *id;
                    // publish the shareable KV prefix for cross-request reuse
                    if cfg.content_cache {
                        let ch = chains_entry(
                            &mut state.chains,
                            cfg.content_cache,
                            &state.no_chains,
                            &r.spec,
                        );
                        let new = instances[iid].kv.commit_hashes(rid, ch.kv_commit());
                        if let Some(d) = state.dirs.as_mut() {
                            d.kv.publish(iid, &new);
                        }
                    }
                    // image embeddings consumed: free image cache (tagged
                    // blocks stay evictable-cached for the next hit)
                    let has_img = instances[iid].img.has_request(rid);
                    if has_img {
                        instances[iid].img.free(rid).unwrap();
                    }
                    let r = instances[iid].queues.find_running(rid).unwrap();
                    if r.finished() {
                        to_finish.push(rid);
                    } else if !mask.decode {
                        to_migrate.push((rid, Stage::Decode));
                    }
                }
            }
            TaskWork::DecodeToken { .. } => {
                r.decoded += 1;
                lc.add_phase(Phase::DecodeQueue, (started - rs).max(0.0));
                lc.add_phase(Phase::DecodeExec, dur);
                lc.record_token(now);
                *rs_slot = now;
                state.tracer.span(SpanKind::DecodeQueue, iid, id.0, rs.min(started), started, 0);
                state.tracer.span(SpanKind::DecodeExec, iid, id.0, started, now, 1);
                if r.finished() {
                    to_finish.push(*id);
                }
            }
            TaskWork::Migrate => {}
        }
    }

    for &id in &to_finish {
        instances[iid].queues.remove_running(id);
        instances[iid].release_all(id);
        if let Some(lc) = state.lifecycles.get_mut(&id.0) {
            lc.finished_at = Some(now);
        }
        // finished: drop the per-request engine state (the lifecycle
        // stays — it IS the result)
        state.ready_since.remove(&id.0);
        state.chains.remove(&id.0);
    }

    // paper §4.3 step 1: notify the target; it pulls when it has capacity
    for &(id, next_stage) in &to_migrate {
        start_migration(instances, iid, id, next_stage, now, state);
    }

    to_finish.clear();
    to_migrate.clear();
    state.scratch.to_finish = to_finish;
    state.scratch.to_migrate = to_migrate;
}

/// Admit pending pulls wherever capacity allows (§4.3 step 2) and schedule
/// their transfers (step 3). The transfer carries only the payload tokens
/// the target's content-addressed cache does not already hold (delta
/// transfer): reserving the pull shares any cached prefix blocks, and the
/// remaining tokens price the link time.
fn process_inboxes(instances: &mut [SimInstance], now: f64, state: &mut EngineState) {
    let cfg = state.cfg;
    let (link_lat, link_bw) = cfg.link();
    for iid in 0..instances.len() {
        let mut i = 0;
        while i < instances[iid].inbox.len() {
            let can = instances[iid].can_admit(&instances[iid].inbox[i].req);
            if can {
                let mut pull = instances[iid].inbox.remove(i);
                let r = pull.req.clone();
                let ch =
                    chains_entry(&mut state.chains, cfg.content_cache, &state.no_chains, &r.spec);
                let (kv_cached, img_cached) = {
                    let SimInstance { kv, img, mask, .. } = &mut instances[iid];
                    reserve_blocks(*mask, kv, img, &r, &ch)
                };
                if let Some(d) = state.dirs.as_mut() {
                    d.sync_evictions(&mut instances[iid]);
                }
                pull.kv_cached = kv_cached;
                let cached = match pull.phase {
                    Phase::EpMigration => img_cached,
                    _ => kv_cached,
                };
                let cached = cached.min(pull.payload_tokens);
                state.report.migration_tokens_saved += cached;
                let bytes = match pull.phase {
                    Phase::EpMigration => crate::costmodel::ops::image_delta_payload_bytes(
                        &cfg.model,
                        pull.payload_tokens,
                        cached,
                    ),
                    _ => crate::costmodel::ops::kv_delta_payload_bytes(
                        &cfg.model,
                        pull.payload_tokens,
                        cached,
                    ),
                };
                let dur = link_lat + bytes / link_bw;
                state.push(
                    now + dur,
                    EvKind::TransferDone { src: pull.src, dst: iid, req: r.spec.id },
                );
                state.tracer.span(SpanKind::Transfer, iid, r.spec.id.0, now, now + dur, bytes as u64);
                instances[iid].incoming.insert(r.spec.id.0, pull);
            } else {
                i += 1; // blocked: backpressure (source keeps its blocks)
            }
        }
    }
}

/// Placeholder scheduler used during the split-borrow swap.
struct NullSched;
impl Scheduler for NullSched {
    fn build_batch(
        &mut self,
        _q: &mut Queues,
        _b: &Budgets,
        _a: &mut crate::scheduler::AdmitFn,
    ) -> Batch {
        Batch::default()
    }
    fn name(&self) -> &'static str {
        "null"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ModelSpec, SloSpec};
    use crate::scheduler::Policy;
    use crate::simulator::ClusterSpec;
    use crate::workload::{Dataset, PoissonGenerator};

    fn run(cluster: &str, policy: Policy, rate: f64, n: usize) -> SimResult {
        let model = ModelSpec::llava15_7b();
        let slo = SloSpec::new(0.25, 0.04);
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse(cluster).unwrap(),
            policy,
            slo,
        );
        let gen = PoissonGenerator::new(Dataset::textcaps(), rate, 42);
        let reqs = gen.generate(&model, n);
        simulate(&cfg, &reqs)
    }

    #[test]
    fn colocated_low_rate_finishes_everything() {
        let res = run("8EPD", Policy::StageLevel, 4.0, 60);
        assert_eq!(res.unfinished, 0, "all requests should finish");
        assert_eq!(res.metrics.num_finished(), 60);
        assert_eq!(res.migrations, 0, "colocated EPD never migrates");
        assert!(res.metrics.ttft().mean() > 0.0);
    }

    #[test]
    fn disaggregated_migrates_and_finishes() {
        let res = run("1E3P4D", Policy::StageLevel, 4.0, 60);
        assert_eq!(res.unfinished, 0);
        // every image request migrates E->P and P->D
        assert!(res.migrations >= 100, "migrations = {}", res.migrations);
        let bd = res.metrics.phase_breakdown();
        assert!(bd[Phase::EpMigration as usize] > 0.0);
        assert!(bd[Phase::PdMigration as usize] > 0.0);
    }

    #[test]
    fn token_latencies_monotone() {
        let res = run("1E3P4D", Policy::StageLevel, 2.0, 40);
        for lc in res.metrics.finished() {
            let t = &lc.token_times;
            assert!(t.windows(2).all(|w| w[1] >= w[0] - 1e-12));
            assert!(lc.ttft().unwrap() >= 0.0);
        }
    }

    #[test]
    fn output_token_counts_exact() {
        let model = ModelSpec::llava15_7b();
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse("8EPD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let gen = PoissonGenerator::new(Dataset::textvqa(), 2.0, 7);
        let reqs = gen.generate(&model, 30);
        let res = simulate(&cfg, &reqs);
        for spec in &reqs {
            let lc = &res.metrics.lifecycles[&spec.id.0];
            assert_eq!(
                lc.token_times.len(),
                spec.output_tokens,
                "request {} should emit exactly its output budget",
                spec.id
            );
        }
    }

    #[test]
    fn overload_degrades_attainment() {
        let lo = run("8EPD", Policy::StageLevel, 2.0, 60);
        let hi = run("8EPD", Policy::StageLevel, 200.0, 120);
        let slo = SloSpec::new(0.25, 0.04);
        let a_lo = lo.metrics.slo_attainment(slo);
        let a_hi = hi.metrics.slo_attainment(slo);
        assert!(
            a_lo > a_hi || (a_lo - a_hi).abs() < 1e-9,
            "attainment must not improve under overload: lo={a_lo} hi={a_hi}"
        );
        assert!(a_lo > 0.8, "low rate should mostly meet SLO, got {a_lo}");
    }

    #[test]
    fn stage_level_beats_prefill_first_on_tpot() {
        // the Fig. 7 story: prefill-first stalls decodes -> worse tail TPOT.
        // Single instance under real pressure so requests actually overlap.
        let ours = run("1EPD", Policy::StageLevel, 6.0, 80);
        let v0 = run("1EPD", Policy::PrefillFirst, 6.0, 80);
        let t_ours = ours.metrics.tpot().p99();
        let t_v0 = v0.metrics.tpot().p99();
        assert!(
            t_ours < t_v0,
            "stage-level p99 TPOT {t_ours} should beat prefill-first {t_v0}"
        );
    }

    #[test]
    fn incomplete_cluster_strands_requests() {
        // no prefill instance: image requests encode, then strand waiting
        // for a P node that never exists — unfinished, not dropped
        let res = run("4E4D", Policy::StageLevel, 2.0, 10);
        assert_eq!(res.metrics.num_finished(), 0);
        assert_eq!(res.unfinished, 10);
        assert_eq!(res.dropped_requests, 0);

        // text-only requests on the same cluster have NO serving candidate
        // at arrival: they are dropped, counted, and leave no
        // half-initialized lifecycle / ready_since state behind
        // (regression: they used to linger as phantom lifecycles)
        let model = ModelSpec::llava15_7b();
        let cfg = SimConfig::new(
            model.clone(),
            ClusterSpec::parse("4E4D").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let text_only = Dataset { image_prob: 0.0, ..Dataset::textcaps() };
        let reqs = PoissonGenerator::new(text_only, 2.0, 5).generate(&model, 10);
        let res = simulate(&cfg, &reqs);
        assert_eq!(res.dropped_requests, 10, "every text request is dropped");
        assert_eq!(res.unfinished, 0, "drops are not 'unfinished' work");
        assert_eq!(res.metrics.len(), 0, "no phantom lifecycles remain");
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        let b = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        assert_eq!(a.batches, b.batches);
        assert_eq!(a.migrations, b.migrations);
        assert!((a.metrics.ttft().mean() - b.metrics.ttft().mean()).abs() < 1e-12);
    }

    // ---- content-addressed reuse -----------------------------------------

    /// A request whose image and prompt prefix recur across the trace.
    fn shared_spec(id: u64, arrival: f64, prompt: usize, out: usize) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival,
            num_images: 1,
            tokens_per_image: 576,
            prompt_tokens: prompt,
            output_tokens: out,
            image_hash: Some(0xCAFE),
            shared_prefix_tokens: prompt.min(32),
            prefix_hash: 0x5157,
        }
    }

    fn sim(cluster: &str, reqs: &[RequestSpec], content_cache: bool) -> SimResult {
        let mut cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.content_cache = content_cache;
        simulate(&cfg, reqs)
    }

    #[test]
    fn repeated_content_hits_cache_and_cuts_latency() {
        let reqs: Vec<RequestSpec> =
            (0..40).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let warm = sim("1EPD", &reqs, true);
        let cold = sim("1EPD", &reqs, false);
        assert_eq!(warm.unfinished, 0);
        assert_eq!(cold.unfinished, 0);
        assert_eq!(cold.cache.img_hit_images, 0);
        assert_eq!(cold.cache.kv_hit_tokens, 0);
        // everything after the first request reuses the image embedding
        // and the shared prefix KV
        assert!(warm.cache.img_hit_images >= 35, "img hits {}", warm.cache.img_hit_images);
        assert!(
            warm.cache.kv_hit_tokens >= 35 * 576,
            "kv hit tokens {}",
            warm.cache.kv_hit_tokens
        );
        assert!(warm.cache.kv_hit_rate() > 0.5);
        // skipped encode + shortened prefill must show up in TTFT
        let (t_warm, t_cold) = (warm.metrics.ttft().mean(), cold.metrics.ttft().mean());
        assert!(t_warm < t_cold, "warm ttft {t_warm} vs cold {t_cold}");
        // identical token accounting either way
        assert_eq!(warm.metrics.num_finished(), cold.metrics.num_finished());
    }

    #[test]
    fn cold_traces_are_bit_identical_with_the_cache_enabled() {
        // all-unique content: enabling the content cache must not change
        // behaviour at all (zero regressions on cold traces)
        let model = ModelSpec::llava15_7b();
        let gen = PoissonGenerator::new(Dataset::textcaps(), 6.0, 13);
        let reqs = gen.generate(&model, 80);
        let on = sim("1E2P1D", &reqs, true);
        let off = sim("1E2P1D", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.unfinished, off.unfinished);
        assert_eq!(on.cache.kv_hit_tokens, 0);
        assert_eq!(on.cache.img_hit_images, 0);
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert!((on.metrics.tpot().mean() - off.metrics.tpot().mean()).abs() < 1e-12);
    }

    #[test]
    fn delta_transfer_skips_bytes_the_target_caches() {
        // disaggregated: the P node commits the shared prefix, the D node
        // commits migrated-in KV; later migrations transfer only deltas
        let reqs: Vec<RequestSpec> =
            (0..24).map(|i| shared_spec(i, i as f64 * 0.5, 48, 6)).collect();
        let warm = sim("1E1P1D", &reqs, true);
        assert_eq!(warm.unfinished, 0);
        assert!(
            warm.cache.migration_tokens_saved > 0,
            "deltas must save transfer tokens"
        );
        let cold = sim("1E1P1D", &reqs, false);
        assert_eq!(cold.cache.migration_tokens_saved, 0);
        assert_eq!(warm.metrics.num_finished(), cold.metrics.num_finished());
    }

    #[test]
    fn cached_image_on_encode_only_node_skips_straight_to_prefill() {
        // request 0 encodes on the E node (committing the embedding);
        // request 1 arrives later with the same image, hits the E node's
        // cache, and must hand itself to the P node without re-encoding
        let reqs = vec![shared_spec(0, 0.0, 40, 3), shared_spec(1, 5.0, 40, 3)];
        let res = sim("1E1P1D", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.cache.img_hit_images, 1);
        let bd = res.metrics.phase_breakdown();
        // only one encode execution across both requests
        assert!(bd[Phase::EncodeExec as usize] > 0.0);
        assert_eq!(res.metrics.num_finished(), 2);
    }

    #[test]
    fn sub_block_images_still_hit_the_embedding_cache() {
        // qwen2-vl-shaped images (380 tokens < IMG_BLOCK) occupy one
        // rounded-up block; acquisition must cap by occupied blocks, not
        // raw image tokens, or repeats would silently never hit
        let reqs: Vec<RequestSpec> = (0..10)
            .map(|i| {
                let mut s = shared_spec(i, i as f64 * 0.4, 24, 3);
                s.tokens_per_image = 380;
                s
            })
            .collect();
        let res = sim("1EPD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert!(
            res.cache.img_hit_images >= 8,
            "sub-block image repeats must hit, got {}",
            res.cache.img_hit_images
        );
    }

    #[test]
    fn interleaved_distinct_images_keep_correctness() {
        // 6 distinct images cycling through one instance: constant
        // hit/miss interleaving across concurrent requests must not
        // corrupt accounting — everything still finishes exactly once
        let reqs: Vec<RequestSpec> = (0..60)
            .map(|i| {
                let mut s = shared_spec(i, i as f64 * 0.2, 32, 3);
                s.image_hash = Some(0x1000 + (i % 6));
                s
            })
            .collect();
        let res = sim("1EPD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.metrics.num_finished(), 60);
        assert!(res.cache.img_hit_images > 40, "repeats hit after first sight");
    }

    // ---- cluster-wide content directory -----------------------------------

    fn sim_dir(cluster: &str, reqs: &[RequestSpec], directory: bool) -> SimResult {
        let mut cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse(cluster).unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        cfg.content_cache = true;
        cfg.cache_directory = directory;
        simulate(&cfg, reqs)
    }

    #[test]
    fn directory_affinity_matches_per_instance_scans_on_warm_traces() {
        // same warm trace, directory on vs off, on a single instance where
        // fetch can never trigger (no peers): the directory's one-sweep
        // affinity must reproduce the per-instance scans exactly
        let reqs: Vec<RequestSpec> =
            (0..40).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let on = sim_dir("1EPD", &reqs, true);
        let off = sim_dir("1EPD", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.cache.img_hit_images, off.cache.img_hit_images);
        assert_eq!(on.cache.kv_hit_tokens, off.cache.kv_hit_tokens);
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert_eq!(on.cache.directory.fetches, 0, "no peers, no fetches");
        assert!(on.cache.directory.publishes > 0, "commits are advertised");
    }

    #[test]
    fn directory_cold_traces_are_bit_identical() {
        // all-unique content: the directory stays empty, so enabling it
        // must change nothing at all — on a multi-instance cluster too
        let model = ModelSpec::llava15_7b();
        let gen = PoissonGenerator::new(Dataset::textcaps(), 6.0, 13);
        let reqs = gen.generate(&model, 80);
        let on = sim_dir("1E2P1D", &reqs, true);
        let off = sim_dir("1E2P1D", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.migrations, off.migrations);
        assert_eq!(on.unfinished, off.unfinished);
        assert_eq!(on.cache.directory.fetches, 0);
        assert_eq!(on.cache.directory.publishes, 0, "unique content never publishes");
        assert!((on.metrics.ttft().mean() - off.metrics.ttft().mean()).abs() < 1e-12);
        assert!((on.metrics.tpot().mean() - off.metrics.tpot().mean()).abs() < 1e-12);
    }

    #[test]
    fn hot_prefix_spillover_fetches_instead_of_reprefilling() {
        // a hot 512-token shared prefix lives on the instance that served
        // it first; affinity herds followers there until its queue passes
        // the router's load cap, and the spillover lands on the cold peer
        // — which must FETCH the prefix KV over the link (sub-ms) instead
        // of re-prefilling 512 tokens (weight-read bound, tens of ms)
        let mk = |id: u64, t: f64| RequestSpec {
            id: RequestId(id),
            arrival: t,
            num_images: 0,
            tokens_per_image: 0,
            prompt_tokens: 600,
            output_tokens: 8,
            image_hash: None,
            shared_prefix_tokens: 512,
            prefix_hash: 0xBEEF,
        };
        // one warmup seeds the prefix on exactly one instance; the dense
        // burst two seconds later herds onto that holder and spills over
        let mut reqs = vec![mk(0, 0.0)];
        for i in 1..30 {
            reqs.push(mk(i, 2.0 + i as f64 * 0.001));
        }
        let res = sim_dir("2PD", &reqs, true);
        assert_eq!(res.unfinished, 0);
        assert_eq!(res.metrics.num_finished(), 30);
        let d = res.cache.directory;
        assert!(d.fetches >= 1, "spillover must fetch, got {d:?}");
        assert!(d.fetched_kv_tokens >= KV_BLOCK);
        assert_eq!(d.stale_fetches, 0, "nothing evicts in this run");
        // the warm cluster must not be slower with fetch-over-recompute on
        let off = sim_dir("2PD", &reqs, false);
        assert_eq!(off.cache.directory.fetches, 0);
        assert!(
            res.metrics.ttft().mean() <= off.metrics.ttft().mean() * 1.05,
            "fetching must not hurt TTFT: on={} off={}",
            res.metrics.ttft().mean(),
            off.metrics.ttft().mean()
        );
    }

    // ---- fetch-plan re-validation under eviction races ---------------------

    /// Engine state for handler-level tests (same construction as
    /// `simulate`, directory on).
    fn handler_state(cfg: &SimConfig, n: usize) -> EngineState<'_> {
        EngineState {
            cfg,
            budgets: Budgets::default(),
            router: Router::new(RoutePolicy::LeastLoaded, cfg.seed),
            tracker: DrainTracker::new(n),
            dirs: Some(DirState {
                kv: ContentDirectory::new(n),
                img: ContentDirectory::new(n),
                report: DirectoryReport::default(),
            }),
            heap: BinaryHeap::new(),
            seq: 0,
            events: 0,
            migrations: 0,
            batches: 0,
            dropped: 0,
            report: CacheReport::default(),
            lifecycles: FxHashMap::default(),
            ready_since: FxHashMap::default(),
            chains: FxHashMap::default(),
            no_chains: Arc::new(HashChains::empty()),
            scratch: Scratch::default(),
            tracer: Tracer::off(),
        }
    }

    /// Text-only spec sharing a hot 512-token prefix.
    fn prefix_spec(id: u64, prompt: usize) -> RequestSpec {
        RequestSpec {
            id: RequestId(id),
            arrival: 0.0,
            num_images: 0,
            tokens_per_image: 0,
            prompt_tokens: prompt,
            output_tokens: 4,
            image_hash: None,
            shared_prefix_tokens: 512,
            prefix_hash: 0xFE7C,
        }
    }

    /// Give `inst` a small KV pool, seed `tokens` of the shared prefix as
    /// unreferenced cached blocks, and advertise them in the directory —
    /// a holder whose content a later filler allocation can evict.
    fn seed_evictable_prefix(
        inst: &mut SimInstance,
        dirs: &mut DirState,
        ch: &HashChains,
        tokens: usize,
        seeder: u64,
    ) {
        let blocks = tokens / KV_BLOCK;
        inst.kv = PagedCache::new(blocks + 4, KV_BLOCK, 1024);
        inst.kv.set_eviction_tracking(true);
        let rid = RequestId(seeder);
        inst.kv.allocate(rid, tokens).unwrap();
        let published = inst.kv.commit_hashes(rid, &ch.kv[..blocks]);
        assert_eq!(published.len(), blocks);
        dirs.kv.publish(inst.id, &published);
        inst.kv.free(rid).unwrap(); // refs drop: cached + evictable
    }

    /// Fill `inst`'s whole small pool so every cached prefix block evicts.
    fn evict_prefix(inst: &mut SimInstance, dirs: &mut DirState, filler: u64) {
        let n = inst.kv.num_blocks();
        inst.kv.allocate(RequestId(filler), n * KV_BLOCK).unwrap();
        dirs.sync_evictions(inst);
    }

    #[test]
    fn stale_fetch_redirects_to_a_surviving_holder() {
        // Holder eviction between fetch planning (arrival) and landing
        // (service) used to burn the fetch: the landing validated against
        // the planned source only, counted `stale_fetches`, and
        // re-prefilled 512 tokens the cluster still held on ANOTHER
        // instance. Landing-time re-validation against the current
        // directory must redirect there instead — strictly fewer stale
        // fetches on this race (1 before, 0 now).
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let mut instances = build_instances(&cfg, &cfg.cluster.instance_masks(), true);
        let mut state = handler_state(&cfg, 3);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = state.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut instances[0], dirs, &ch, 512, 100);
            seed_evictable_prefix(&mut instances[1], dirs, &ch, 512, 101);
        }

        // arrival at instance 2: plan the fetch (lowest-index holder on
        // equal loads -> source 0), park the request
        let mut st = ReqState::new(spec.clone());
        state.chains.insert(1, ch.clone());
        instances[2].attach(&mut st, &ch.kv, &ch.img, &mut state.report);
        let parked = maybe_start_fetch(&mut instances, 2, st, &ch, 0.0, &mut state);
        assert!(parked.is_none(), "a worthwhile fetch parks the request");
        assert_eq!(instances[2].fetching[&1].kv_src, Some((0, 512)));
        assert_eq!(state.dirs.as_ref().unwrap().report.fetches, 1);

        // the race: holder 0 evicts the prefix before the fetch lands
        {
            let dirs = state.dirs.as_mut().unwrap();
            evict_prefix(&mut instances[0], dirs, 900);
        }
        assert_eq!(instances[0].kv.lookup_prefix(&ch.kv[..32]), 0, "content gone");

        // landing: stale source, but holder 1 survives -> redirect
        let ev = state.heap.pop().expect("landing scheduled");
        handle_fetch_done(&mut instances, 2, RequestId(1), ev.t, &mut state);
        let d = state.dirs.as_ref().unwrap().report;
        assert_eq!(d.stale_fetches, 0, "re-validation rescued the fetch");
        assert_eq!(d.redirected_fetches, 1);
        assert_eq!(
            instances[2].fetching[&1].kv_src,
            Some((1, 512)),
            "redirected to the surviving holder"
        );

        // second landing commits from the survivor and resumes dispatch
        let ev = state.heap.pop().expect("redirect scheduled a new landing");
        handle_fetch_done(&mut instances, 2, RequestId(1), ev.t, &mut state);
        assert!(instances[2].fetching.is_empty());
        let d = state.dirs.as_ref().unwrap().report;
        assert_eq!(d.stale_fetches, 0);
        assert_eq!(d.fetched_kv_tokens, 512);
        let r = instances[2].queues.peek_waiting(|_| true).expect("request dispatched");
        assert_eq!(r.prefilled, 512, "prefill resumes at the fetched prefix");
    }

    #[test]
    fn stale_fetch_with_no_surviving_holder_falls_back_to_recompute() {
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let mut instances = build_instances(&cfg, &cfg.cluster.instance_masks(), true);
        let mut state = handler_state(&cfg, 3);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = state.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut instances[0], dirs, &ch, 512, 100);
        }
        let mut st = ReqState::new(spec.clone());
        state.chains.insert(1, ch.clone());
        instances[2].attach(&mut st, &ch.kv, &ch.img, &mut state.report);
        assert!(maybe_start_fetch(&mut instances, 2, st, &ch, 0.0, &mut state).is_none());
        {
            let dirs = state.dirs.as_mut().unwrap();
            evict_prefix(&mut instances[0], dirs, 900);
        }
        let ev = state.heap.pop().unwrap();
        handle_fetch_done(&mut instances, 2, RequestId(1), ev.t, &mut state);
        let d = state.dirs.as_ref().unwrap().report;
        assert_eq!(d.stale_fetches, 1, "no holder left: doomed fetch recomputes");
        assert_eq!(d.redirected_fetches, 0);
        assert_eq!(d.fetched_kv_tokens, 0);
        assert!(instances[2].fetching.is_empty(), "request not stuck parked");
        let r = instances[2].queues.peek_waiting(|_| true).expect("request dispatched");
        assert_eq!(r.prefilled, 0, "full recompute from scratch");
    }

    #[test]
    fn one_redirect_cap_prevents_chasing_a_churning_directory() {
        let cfg = SimConfig::new(
            ModelSpec::llava15_7b(),
            ClusterSpec::parse("3PD").unwrap(),
            Policy::StageLevel,
            SloSpec::new(0.25, 0.04),
        );
        let mut instances = build_instances(&cfg, &cfg.cluster.instance_masks(), true);
        let mut state = handler_state(&cfg, 3);
        let spec = prefix_spec(1, 600);
        let ch = Arc::new(HashChains::of_spec(&spec, KV_BLOCK, IMG_BLOCK));
        {
            let dirs = state.dirs.as_mut().unwrap();
            seed_evictable_prefix(&mut instances[0], dirs, &ch, 512, 100);
            seed_evictable_prefix(&mut instances[1], dirs, &ch, 512, 101);
        }
        let mut st = ReqState::new(spec.clone());
        state.chains.insert(1, ch.clone());
        instances[2].attach(&mut st, &ch.kv, &ch.img, &mut state.report);
        assert!(maybe_start_fetch(&mut instances, 2, st, &ch, 0.0, &mut state).is_none());
        // both holders churn away, one before each landing
        {
            let dirs = state.dirs.as_mut().unwrap();
            evict_prefix(&mut instances[0], dirs, 900);
        }
        let ev = state.heap.pop().unwrap();
        handle_fetch_done(&mut instances, 2, RequestId(1), ev.t, &mut state);
        assert_eq!(state.dirs.as_ref().unwrap().report.redirected_fetches, 1);
        {
            let dirs = state.dirs.as_mut().unwrap();
            evict_prefix(&mut instances[1], dirs, 901);
        }
        let ev = state.heap.pop().unwrap();
        handle_fetch_done(&mut instances, 2, RequestId(1), ev.t, &mut state);
        let d = state.dirs.as_ref().unwrap().report;
        assert_eq!(d.stale_fetches, 1, "second stale landing gives up");
        assert_eq!(d.redirected_fetches, 1, "no second redirect");
        assert!(instances[2].fetching.is_empty());
        assert_eq!(
            instances[2].queues.peek_waiting(|_| true).unwrap().prefilled,
            0,
            "recompute from scratch"
        );
    }

    // ---- hot-path overhaul ------------------------------------------------

    #[test]
    fn digest_pins_behaviour_and_events_are_counted() {
        let a = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        let b = run("1E3P4D", Policy::StageLevel, 3.0, 40);
        assert_eq!(a.digest(), b.digest(), "seeded runs must be bit-identical");
        assert!(a.events > 0, "the loop processed events");
        assert_eq!(a.events, b.events, "event counts are deterministic too");
        // a different trace must produce a different fingerprint
        let c = run("1E3P4D", Policy::StageLevel, 2.0, 40);
        assert_ne!(a.digest(), c.digest(), "digest is workload-sensitive");
    }

    #[test]
    fn digest_is_stable_across_cache_and_directory_modes_on_warm_traces() {
        // single instance: the directory's one-sweep affinity must
        // reproduce the per-instance scans exactly, digest included
        let reqs: Vec<RequestSpec> =
            (0..30).map(|i| shared_spec(i, i as f64 * 0.25, 40, 4)).collect();
        let on = sim_dir("1EPD", &reqs, true);
        let off = sim_dir("1EPD", &reqs, false);
        assert_eq!(on.batches, off.batches);
        assert_eq!(on.metrics.num_finished(), off.metrics.num_finished());
        // no peers => no fetches either way, so even the digest agrees
        assert_eq!(on.digest(), off.digest());
    }
}
