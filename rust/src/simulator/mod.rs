//! Roofline-calibrated discrete-event simulator of a HydraInfer cluster.
//!
//! This is the experiment substrate standing in for the paper's 8×H800
//! node (see DESIGN.md §2): instances execute batches whose duration comes
//! from the analytic cost model (`costmodel`), requests migrate between
//! instances over a modeled interconnect using the paper's 4-step
//! pull-based protocol, and every scheduling decision — Algorithm 1 or a
//! baseline policy — runs the *actual* scheduler implementations from
//! `crate::scheduler`. All of Figs. 7 and 10–14 regenerate from here.

pub mod engine;

pub use engine::{simulate, CacheReport, DirectoryReport, SimResult};

/// Shard owning instance `inst` when `n` instances are split into
/// `shards` contiguous, near-equal groups (the first `n % shards` groups
/// get one extra instance). A **pure function of the instance id and the
/// cluster size** — deliberately independent of instance roles, so a
/// controller role flip mid-run can never move an instance's state across
/// shards (the property test in `tests/shard_partition.rs` pins this).
pub fn shard_of(inst: usize, n: usize, shards: usize) -> usize {
    let shards = shards.clamp(1, n.max(1));
    let base = n / shards;
    let extra = n % shards; // first `extra` shards own `base + 1` instances
    let big = extra * (base + 1);
    if inst < big {
        inst / (base + 1)
    } else {
        extra + (inst - big) / base.max(1)
    }
}

/// `[lo, hi)` global-instance ranges per shard under [`shard_of`]'s
/// contiguous partition.
pub fn shard_bounds(n: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, n.max(1));
    let base = n / shards;
    let extra = n % shards;
    let mut out = Vec::with_capacity(shards);
    let mut lo = 0;
    for s in 0..shards {
        let len = base + usize::from(s < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

use crate::config::{ControllerConfig, DeviceSpec, ModelSpec, SloSpec};
use crate::scheduler::{Policy, StageMask};
use crate::util::ceil_div;

/// KV cache block size in tokens (matches the paper's setup, §5.1).
pub const KV_BLOCK: usize = 16;
/// Image cache block size in image tokens (paper: 576 — one LLaVA image).
pub const IMG_BLOCK: usize = 576;

/// Cluster layout: instance groups, e.g. `[(E,1), (P,3), (D,4)]` = "1E3P4D".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClusterSpec {
    pub groups: Vec<(StageMask, usize)>,
}

impl ClusterSpec {
    pub fn new(groups: Vec<(StageMask, usize)>) -> Self {
        ClusterSpec { groups }
    }

    /// Total instances (one GPU each).
    pub fn num_instances(&self) -> usize {
        self.groups.iter().map(|(_, n)| n).sum()
    }

    /// Expand into one mask per instance.
    pub fn instance_masks(&self) -> Vec<StageMask> {
        let mut v = Vec::new();
        for &(mask, n) in &self.groups {
            for _ in 0..n {
                v.push(mask);
            }
        }
        v
    }

    /// Label like "1E3P4D" / "2EP6D" / "8EPD".
    pub fn label(&self) -> String {
        self.groups
            .iter()
            .map(|(m, n)| format!("{n}{}", m.label()))
            .collect::<Vec<_>>()
            .join("")
    }

    /// Parse "1E3P4D", "2EP6D", "8EPD", "1ED7P"...
    pub fn parse(s: &str) -> anyhow::Result<ClusterSpec> {
        let bytes = s.as_bytes();
        let mut groups = Vec::new();
        let mut i = 0;
        while i < bytes.len() {
            let start = i;
            while i < bytes.len() && bytes[i].is_ascii_digit() {
                i += 1;
            }
            if i == start {
                anyhow::bail!("expected a count at `{}` in `{s}`", &s[i..]);
            }
            let n: usize = s[start..i].parse()?;
            let lstart = i;
            while i < bytes.len() && matches!(bytes[i], b'E' | b'P' | b'D') {
                i += 1;
            }
            if i == lstart {
                anyhow::bail!("expected stage letters at `{}` in `{s}`", &s[i..]);
            }
            let letters = &s[lstart..i];
            let mask = StageMask {
                encode: letters.contains('E'),
                prefill: letters.contains('P'),
                decode: letters.contains('D'),
            };
            if n == 0 {
                anyhow::bail!("zero-count group in `{s}`");
            }
            groups.push((mask, n));
        }
        if groups.is_empty() {
            anyhow::bail!("empty cluster spec");
        }
        Ok(ClusterSpec { groups })
    }

    /// Does the cluster cover all three stages?
    pub fn complete(&self) -> bool {
        let masks = self.instance_masks();
        masks.iter().any(|m| m.encode)
            && masks.iter().any(|m| m.prefill)
            && masks.iter().any(|m| m.decode)
    }
}

/// Interconnect backend for cache migration (paper §4.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferBackend {
    /// CUDA-IPC-style handles: lowest latency, intra-node only.
    CudaIpc,
    /// NCCL: higher latency floor, intra- and inter-node.
    Nccl,
}

/// Full simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub model: ModelSpec,
    pub device: DeviceSpec,
    pub cluster: ClusterSpec,
    pub policy: Policy,
    /// SLO used for budget profiling (Alg. 1 line 1–2) and attainment.
    pub slo: SloSpec,
    /// Vision/language multi-stream colocation (ours: on; baselines: off).
    pub multistream: bool,
    pub backend: TransferBackend,
    /// Simulation horizon, seconds.
    pub horizon: f64,
    /// Router seed.
    pub seed: u64,
    /// Per-scheduling-iteration engine overhead, seconds. The paper's
    /// testbed runs Python engines in eager mode with CUDA graphs off
    /// (§5.1), so every iteration pays ~20ms of scheduler + launch CPU
    /// time on top of kernel time — this is what makes the TPOT SLO bind
    /// and scheduling policy matter. Applies to ALL engines (HydraInfer
    /// itself is a Python engine in the paper).
    pub engine_overhead: f64,
    /// Elastic control plane (`crate::controller`): when set, a periodic
    /// controller tick estimates per-stage load and may drain-then-flip
    /// instance roles online. None = static layout (the paper's setup).
    pub controller: Option<ControllerConfig>,
    /// Content-addressed cache reuse (§4.5 extension): share KV-prefix and
    /// image-embedding blocks across requests, route with cache affinity,
    /// and delta-transfer migrations. On a trace with no repeated content
    /// this is behaviour-identical to `false`; disable it only for
    /// cold-cache baselines (`bench_prefix_reuse`).
    pub content_cache: bool,
    /// Cluster-wide content directory (`cache::ContentDirectory`): routing
    /// affinity comes from one hash-chain sweep instead of per-candidate
    /// index scans, and requests **fetch** content a peer holds instead of
    /// recomputing it whenever the cost model prices the transfer below
    /// the encode/prefill it replaces (fetch-over-recompute). Requires
    /// `content_cache`. Off reproduces the per-instance-affinity behaviour
    /// bit-for-bit; on, traces with no repeated content are also
    /// bit-identical (an empty directory never fetches).
    pub cache_directory: bool,
    /// Stage-span flight recorder (`obs::trace`): when on, the engine
    /// records a span for every queue/exec/migration/transfer/fetch
    /// segment and role-flip mark into a preallocated ring, surfaced as
    /// [`SimResult::trace`]. Guaranteed not to reschedule: digests are
    /// bit-identical on or off (golden suite), and off costs one branch
    /// per emission site and zero allocations (`bench_sim_hotpath`).
    pub trace: bool,
    /// Ring capacity (spans) when `trace` is on; the oldest spans are
    /// overwritten once full — flight-recorder semantics.
    pub trace_capacity: usize,
    /// Event-engine shards (parallel worker threads). The engine windows
    /// simulated time and runs every shard's events for a window
    /// concurrently; cross-shard effects (transfer landings, fetch
    /// sources, directory gossip, migration retargets) are exchanged only
    /// at window barriers. The window protocol is applied **at every
    /// shard count, including 1**, so `SimResult::digest()` is
    /// bit-identical for any `shards` value — the golden suite asserts
    /// `shards ∈ {1, 2, 4}` agree on every pinned shape. Clamped to the
    /// instance count.
    pub shards: usize,
    /// Barrier window length Δ in simulated seconds; `0.0` derives it
    /// from the interconnect (`max(link latency, 2ms)`). Δ bounds how
    /// stale the routing view and cross-shard messages may be — it is a
    /// *fidelity* knob, not a correctness knob: digests never depend on
    /// the shard count, only on Δ itself.
    pub window: f64,
    /// Deterministic fault schedule (`crate::faults`): seeded instance
    /// crash/recover events, link degradation windows, and straggler
    /// slowdowns, applied at window barriers in canonical order. The
    /// default empty plan is behaviourally invisible — digests with an
    /// empty plan are bit-identical to a build without the fault
    /// subsystem (golden suite pins this).
    pub faults: crate::faults::FaultPlan,
}

impl SimConfig {
    pub fn new(model: ModelSpec, cluster: ClusterSpec, policy: Policy, slo: SloSpec) -> Self {
        SimConfig {
            model,
            device: DeviceSpec::h800(),
            cluster,
            policy,
            slo,
            multistream: policy == Policy::StageLevel,
            backend: TransferBackend::CudaIpc,
            horizon: 600.0,
            seed: 0,
            engine_overhead: 0.020,
            controller: None,
            content_cache: true,
            cache_directory: true,
            trace: false,
            trace_capacity: 1 << 16,
            shards: 1,
            window: 0.0,
            faults: Default::default(),
        }
    }

    /// Effective barrier window Δ (resolves the `window == 0.0` default).
    pub fn effective_window(&self) -> f64 {
        if self.window > 0.0 {
            self.window
        } else {
            self.link().0.max(0.002)
        }
    }

    /// Migration link parameters (latency floor, bandwidth).
    pub fn link(&self) -> (f64, f64) {
        match self.backend {
            TransferBackend::CudaIpc => (self.device.ipc_latency, self.device.nvlink_bw),
            TransferBackend::Nccl => (self.device.nccl_latency, self.device.nvlink_bw),
        }
    }
}

/// Per-instance cache capacity in blocks, derived from the HBM budget and
/// which models the instance loads (paper §3.3: encode nodes skip the LM
/// and KV cache entirely, so they support far more concurrent images).
pub fn cache_blocks(model: &ModelSpec, device: &DeviceSpec, mask: StageMask) -> (usize, usize) {
    let mut weights = 0.0;
    if mask.encode {
        weights += model.vision_params() as f64 * model.dtype_bytes as f64;
    }
    if mask.prefill || mask.decode {
        weights += model.lm_params() as f64 * model.dtype_bytes as f64;
    }
    let usable = (device.hbm_capacity - weights).max(0.0) * 0.9; // activations margin

    let kv_block_bytes =
        (2 * model.lm.layers * KV_BLOCK * model.lm.kv_hidden() * model.dtype_bytes) as f64;
    let img_block_bytes = (IMG_BLOCK * model.lm.hidden * model.dtype_bytes) as f64;

    let needs_kv = mask.prefill || mask.decode;
    let needs_img = mask.encode || mask.prefill;
    match (needs_kv, needs_img) {
        (true, true) => {
            let kv = (usable * 0.85 / kv_block_bytes) as usize;
            let img = (usable * 0.15 / img_block_bytes) as usize;
            (kv.max(1), img.max(1))
        }
        (true, false) => (((usable / kv_block_bytes) as usize).max(1), 0),
        (false, true) => (0, ((usable / img_block_bytes) as usize).max(1)),
        (false, false) => (0, 0),
    }
}

/// Image-cache blocks a request occupies.
pub fn img_blocks_for(img_tokens: usize) -> usize {
    ceil_div(img_tokens, IMG_BLOCK)
}

/// KV-cache blocks for `tokens` of context.
pub fn kv_blocks_for(tokens: usize) -> usize {
    ceil_div(tokens, KV_BLOCK)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_label_roundtrip() {
        for s in ["1E3P4D", "2EP6D", "8EPD", "1ED7P", "4E4D"] {
            let c = ClusterSpec::parse(s).unwrap();
            assert_eq!(c.label(), s);
        }
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(ClusterSpec::parse("").is_err());
        assert!(ClusterSpec::parse("E3P").is_err());
        assert!(ClusterSpec::parse("3X").is_err());
        assert!(ClusterSpec::parse("0E1P1D").is_err());
    }

    #[test]
    fn completeness() {
        assert!(ClusterSpec::parse("1E3P4D").unwrap().complete());
        assert!(ClusterSpec::parse("8EPD").unwrap().complete());
        assert!(!ClusterSpec::parse("4E4D").unwrap().complete());
    }

    #[test]
    fn num_instances_sums_groups() {
        assert_eq!(ClusterSpec::parse("1E3P4D").unwrap().num_instances(), 8);
        assert_eq!(ClusterSpec::parse("8EPD").unwrap().num_instances(), 8);
    }

    #[test]
    fn encode_only_instances_fit_more_images() {
        // §3.3: E nodes don't load the LM or hold KV -> far more image blocks
        let m = ModelSpec::llava15_7b();
        let d = DeviceSpec::h800();
        let (_, img_e) = cache_blocks(&m, &d, StageMask::E);
        let (_, img_epd) = cache_blocks(&m, &d, StageMask::EPD);
        assert!(img_e > 4 * img_epd, "E={img_e} EPD={img_epd}");
        let (kv_d, img_d) = cache_blocks(&m, &d, StageMask::D);
        assert_eq!(img_d, 0);
        assert!(kv_d > 1000, "D kv blocks = {kv_d}");
    }

    #[test]
    fn block_math() {
        assert_eq!(img_blocks_for(576), 1);
        assert_eq!(img_blocks_for(577), 2);
        assert_eq!(img_blocks_for(2880), 5); // LLaVA-NeXT max
        assert_eq!(kv_blocks_for(0), 0);
        assert_eq!(kv_blocks_for(17), 2);
    }

    #[test]
    fn shard_partition_is_contiguous_balanced_and_total() {
        for n in [1usize, 2, 3, 7, 8, 64, 1000] {
            for shards in [1usize, 2, 3, 4, 16, 2000] {
                let bounds = shard_bounds(n, shards);
                let eff = shards.clamp(1, n);
                assert_eq!(bounds.len(), eff);
                assert_eq!(bounds[0].0, 0);
                assert_eq!(bounds[eff - 1].1, n);
                for w in bounds.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "contiguous");
                }
                let sizes: Vec<usize> = bounds.iter().map(|(l, h)| h - l).collect();
                let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "near-equal: {sizes:?}");
                for inst in 0..n {
                    let s = shard_of(inst, n, shards);
                    let (lo, hi) = bounds[s];
                    assert!(lo <= inst && inst < hi, "shard_of agrees with bounds");
                }
            }
        }
    }

    #[test]
    fn effective_window_floors_at_link_latency() {
        let m = ModelSpec::llava15_7b();
        let c = ClusterSpec::parse("8EPD").unwrap();
        let mut cfg = SimConfig::new(m, c, Policy::StageLevel, SloSpec::new(0.25, 0.04));
        assert!(cfg.effective_window() >= cfg.link().0);
        assert!(cfg.effective_window() >= 0.002);
        cfg.window = 0.25;
        assert_eq!(cfg.effective_window(), 0.25);
    }

    #[test]
    fn link_latency_orders() {
        let m = ModelSpec::llava15_7b();
        let c = ClusterSpec::parse("8EPD").unwrap();
        let mut cfg = SimConfig::new(m, c, Policy::StageLevel, SloSpec::new(0.25, 0.04));
        let (ipc_lat, _) = cfg.link();
        cfg.backend = TransferBackend::Nccl;
        let (nccl_lat, _) = cfg.link();
        assert!(ipc_lat < nccl_lat);
    }
}
