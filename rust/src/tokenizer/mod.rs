//! Byte-level tokenizer + chat template.
//!
//! The tiny VLM's vocabulary is 256 byte tokens + specials, matching
//! `python/compile/model.py::CFG` (BOS=256, EOS=257, IMG=258; vocab padded
//! to 272). Byte-level means lossless round-trips with zero external vocab
//! files — the right substrate for a reproduction whose experiments are
//! about *scheduling*, not language quality.
//!
//! The chat template mirrors the paper's evaluation setup: every engine
//! under comparison must see the same prompt bytes (§5.1 "All inference
//! engines use the same chat template").

pub const BOS: u32 = 256;
pub const EOS: u32 = 257;
pub const IMG: u32 = 258;
pub const VOCAB: usize = 272;

/// Byte-level tokenizer.
#[derive(Debug, Clone, Default)]
pub struct Tokenizer;

impl Tokenizer {
    pub fn new() -> Self {
        Tokenizer
    }

    /// Encode UTF-8 text to byte tokens (no specials added).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.as_bytes().iter().map(|&b| b as u32).collect()
    }

    /// Decode tokens back to text; specials are dropped, invalid UTF-8
    /// replaced (decode output is advisory — sampling over random weights).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens
            .iter()
            .filter(|&&t| t < 256)
            .map(|&t| t as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Apply the chat template used by all engines in the evaluation:
    /// `BOS [IMG] USER: <prompt> ASSISTANT:`; the IMG sentinel marks where
    /// image embeddings splice in (positions [0, T_IMG) after BOS in the
    /// multimodal prefill convention).
    pub fn apply_chat_template(&self, prompt: &str, has_image: bool) -> Vec<u32> {
        let mut out = vec![BOS];
        if has_image {
            out.push(IMG);
        }
        out.extend(self.encode("USER: "));
        out.extend(self.encode(prompt));
        out.extend(self.encode(" ASSISTANT:"));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = Tokenizer::new();
        let toks = t.encode("hello, world");
        assert_eq!(t.decode(&toks), "hello, world");
    }

    #[test]
    fn roundtrip_utf8() {
        let t = Tokenizer::new();
        let s = "café ✓ 多模态";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn all_tokens_in_vocab() {
        let t = Tokenizer::new();
        for tok in t.apply_chat_template("what is in the image? ✓", true) {
            assert!((tok as usize) < VOCAB, "token {tok} out of vocab");
        }
    }

    #[test]
    fn template_structure() {
        let t = Tokenizer::new();
        let mm = t.apply_chat_template("q", true);
        let txt = t.apply_chat_template("q", false);
        assert_eq!(mm[0], BOS);
        assert_eq!(mm[1], IMG);
        assert_eq!(txt[0], BOS);
        assert_ne!(txt[1], IMG);
        assert_eq!(mm.len(), txt.len() + 1);
    }

    #[test]
    fn decode_drops_specials() {
        let t = Tokenizer::new();
        assert_eq!(t.decode(&[BOS, b'h' as u32, b'i' as u32, EOS]), "hi");
    }
}
