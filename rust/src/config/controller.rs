//! Elastic control-plane configuration (the knobs of `crate::controller`).
//!
//! The offline planner (§4.4) picks the *initial* instance layout; the
//! online controller then watches per-stage load and flips instance roles
//! when the workload drifts. Everything that governs how eagerly it reacts
//! lives here so experiments (and the `--elastic` CLI surface) can sweep
//! it like any other config.

use crate::util::json::Json;

/// Configuration of the online stage-load controller.
///
/// Defaults are deliberately conservative: a flip costs a drain, so the
/// imbalance must be real (ratio trigger), sustained (`sustain_ticks`
/// consecutive observations), and not follow another flip too closely
/// (`cooldown`). Together these three form the hysteresis that prevents
/// flapping under oscillating load.
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerConfig {
    /// Seconds between controller evaluations.
    pub tick: f64,
    /// Rolling estimation window, seconds (queue samples + TTFT/TPOT tails).
    pub window: f64,
    /// Minimum samples in the window before the policy may act.
    pub min_samples: usize,
    /// Consecutive imbalanced ticks required to trigger a flip (halved when
    /// the windowed TTFT/TPOT tails already violate the SLO).
    pub sustain_ticks: usize,
    /// Hot-stage pressure must exceed `imbalance_ratio` x cold-stage
    /// pressure to count as imbalanced.
    pub imbalance_ratio: f64,
    /// Absolute floor on hot-stage pressure (seconds of queued work per
    /// serving instance) — tiny absolute backlogs never trigger.
    pub min_pressure: f64,
    /// Cold-stage pressure is floored at this value inside the ratio test
    /// (avoids division by ~zero when a stage is completely idle).
    pub pressure_floor: f64,
    /// Predicted post-flip bottleneck pressure must drop below
    /// `accept_margin` x the current bottleneck for the flip to proceed.
    pub accept_margin: f64,
    /// Minimum seconds between role flips.
    pub cooldown: f64,
    /// A drain that has not emptied after this many seconds is cancelled
    /// (the instance keeps its current role).
    pub drain_timeout: f64,
}

impl Default for ControllerConfig {
    fn default() -> Self {
        ControllerConfig {
            tick: 0.5,
            window: 10.0,
            min_samples: 4,
            sustain_ticks: 3,
            imbalance_ratio: 2.0,
            min_pressure: 0.25,
            pressure_floor: 0.05,
            accept_margin: 0.95,
            cooldown: 5.0,
            drain_timeout: 30.0,
        }
    }
}

impl ControllerConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("tick", Json::num(self.tick)),
            ("window", Json::num(self.window)),
            ("min_samples", Json::num(self.min_samples as f64)),
            ("sustain_ticks", Json::num(self.sustain_ticks as f64)),
            ("imbalance_ratio", Json::num(self.imbalance_ratio)),
            ("min_pressure", Json::num(self.min_pressure)),
            ("pressure_floor", Json::num(self.pressure_floor)),
            ("accept_margin", Json::num(self.accept_margin)),
            ("cooldown", Json::num(self.cooldown)),
            ("drain_timeout", Json::num(self.drain_timeout)),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ControllerConfig> {
        let d = ControllerConfig::default();
        let f = |key: &str, def: f64| j.get(key).and_then(Json::as_f64).unwrap_or(def);
        let u = |key: &str, def: usize| j.get(key).and_then(Json::as_usize).unwrap_or(def);
        let cfg = ControllerConfig {
            tick: f("tick", d.tick),
            window: f("window", d.window),
            min_samples: u("min_samples", d.min_samples),
            sustain_ticks: u("sustain_ticks", d.sustain_ticks),
            imbalance_ratio: f("imbalance_ratio", d.imbalance_ratio),
            min_pressure: f("min_pressure", d.min_pressure),
            pressure_floor: f("pressure_floor", d.pressure_floor),
            accept_margin: f("accept_margin", d.accept_margin),
            cooldown: f("cooldown", d.cooldown),
            drain_timeout: f("drain_timeout", d.drain_timeout),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.tick > 0.0, "tick must be positive");
        anyhow::ensure!(self.window >= self.tick, "window must cover >= one tick");
        anyhow::ensure!(self.imbalance_ratio >= 1.0, "imbalance_ratio must be >= 1");
        anyhow::ensure!(self.accept_margin > 0.0 && self.accept_margin <= 1.0,
            "accept_margin must be in (0, 1]");
        anyhow::ensure!(self.cooldown >= 0.0 && self.drain_timeout > 0.0,
            "cooldown/drain_timeout must be non-negative/positive");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        ControllerConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip() {
        let mut c = ControllerConfig::default();
        c.tick = 0.25;
        c.sustain_ticks = 5;
        c.cooldown = 2.0;
        let j = c.to_json().to_string();
        let c2 = ControllerConfig::from_json(&crate::util::json::parse(&j).unwrap()).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn missing_fields_fall_back_to_defaults() {
        let j = crate::util::json::parse("{\"tick\": 1.0}").unwrap();
        let c = ControllerConfig::from_json(&j).unwrap();
        assert_eq!(c.tick, 1.0);
        assert_eq!(c.window, ControllerConfig::default().window);
    }

    #[test]
    fn bad_configs_rejected() {
        let mut c = ControllerConfig::default();
        c.tick = 0.0;
        assert!(c.validate().is_err());
        let mut c = ControllerConfig::default();
        c.imbalance_ratio = 0.5;
        assert!(c.validate().is_err());
        let mut c = ControllerConfig::default();
        c.accept_margin = 1.5;
        assert!(c.validate().is_err());
    }
}
