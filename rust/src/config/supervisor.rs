//! Supervision knobs for the real serving plane (PR 9).
//!
//! The real cluster runs a supervisor thread that scans per-instance
//! heartbeats and marks instances dead when they go silent; dead
//! instances stop receiving new work, their in-flight requests are
//! re-dispatched to live peers (bounded by
//! [`crate::faults::RetryPolicy`]), and requests with no live candidate
//! left are dead-lettered with a structured error instead of dropped.

use crate::faults::RetryPolicy;

/// Configuration for the real plane's [`Supervisor`] loop.
///
/// [`Supervisor`]: crate::instance::RealCluster
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SupervisorConfig {
    /// How often (seconds) the supervisor thread scans heartbeats.
    pub heartbeat_interval: f64,
    /// An instance whose last heartbeat is older than this (seconds) is
    /// marked dead. Must comfortably exceed the longest single batch an
    /// instance can execute, or healthy-but-busy instances flap; the
    /// epoch/dedup machinery makes a false positive safe (duplicate
    /// finishes are dropped), but it still costs a redundant dispatch.
    pub dead_after: f64,
    /// Backoff schedule shared by submit-side send retries, in-instance
    /// batch retries, and cluster-side re-dispatch of work stranded on a
    /// dead instance.
    pub retry: RetryPolicy,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            heartbeat_interval: 0.05,
            dead_after: 2.0,
            retry: RetryPolicy::default(),
        }
    }
}

impl SupervisorConfig {
    /// `heartbeat_interval` as a [`std::time::Duration`] for sleep calls.
    pub fn scan_period(&self) -> std::time::Duration {
        std::time::Duration::from_secs_f64(self.heartbeat_interval.max(1e-3))
    }

    /// Heartbeat age (milliseconds) beyond which an instance is dead.
    pub fn dead_after_ms(&self) -> u64 {
        (self.dead_after.max(0.0) * 1e3) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = SupervisorConfig::default();
        assert!(c.heartbeat_interval > 0.0);
        assert!(c.dead_after > c.heartbeat_interval * 4.0, "scan must out-sample the deadline");
        assert_eq!(c.dead_after_ms(), 2000);
        assert_eq!(c.scan_period(), std::time::Duration::from_millis(50));
        assert!(c.retry.max_attempts >= 1);
    }

    #[test]
    fn scan_period_never_degenerates_to_zero() {
        let c = SupervisorConfig { heartbeat_interval: 0.0, ..Default::default() };
        assert!(c.scan_period() > std::time::Duration::ZERO);
    }
}
