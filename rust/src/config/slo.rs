//! SLO settings (paper Table 3): per model × dataset TTFT / TPOT targets.

/// A TTFT/TPOT service-level objective pair, seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    pub ttft: f64,
    pub tpot: f64,
}

impl SloSpec {
    pub fn new(ttft: f64, tpot: f64) -> Self {
        SloSpec { ttft, tpot }
    }

    /// Paper Table 3: the SLO used for (model, dataset) in Fig. 10.
    pub fn paper_table3(model: &str, dataset: &str) -> Option<SloSpec> {
        let s = |ttft: f64, tpot: f64| Some(SloSpec::new(ttft, tpot));
        match (model, dataset) {
            ("llava-1.5-7b", "vizwiz") => s(8.0, 0.04),
            ("llava-1.5-7b", "textvqa") => s(0.25, 0.04),
            ("llava-1.5-7b", "mme") => s(0.25, 0.06),
            ("llava-1.5-7b", "pope") => s(0.25, 0.04),
            ("llava-1.5-7b", "textcaps") => s(0.25, 0.04),
            ("llava-next-7b", "vizwiz") => s(8.0, 0.12),
            ("llava-next-7b", "textvqa") => s(8.0, 0.12),
            ("llava-next-7b", "mme") => s(8.0, 0.14),
            ("llava-next-7b", "pope") => s(8.0, 0.06),
            ("llava-next-7b", "textcaps") => s(8.0, 0.08),
            ("qwen2-vl-7b", "vizwiz") => s(8.0, 0.14),
            ("qwen2-vl-7b", "textvqa") => s(1.0, 0.12),
            ("qwen2-vl-7b", "mme") => s(1.0, 0.14),
            ("qwen2-vl-7b", "pope") => s(1.0, 0.04),
            ("qwen2-vl-7b", "textcaps") => s(1.0, 0.14),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_complete() {
        for model in crate::config::ModelSpec::ALL_NAMES {
            for ds in ["vizwiz", "textvqa", "mme", "pope", "textcaps"] {
                let slo = SloSpec::paper_table3(model, ds);
                assert!(slo.is_some(), "missing SLO for {model}/{ds}");
                let slo = slo.unwrap();
                assert!(slo.ttft > 0.0 && slo.tpot > 0.0);
            }
        }
    }

    #[test]
    fn table3_spot_checks() {
        let s = SloSpec::paper_table3("llava-1.5-7b", "mme").unwrap();
        assert_eq!((s.ttft, s.tpot), (0.25, 0.06));
        let s = SloSpec::paper_table3("qwen2-vl-7b", "pope").unwrap();
        assert_eq!((s.ttft, s.tpot), (1.0, 0.04));
        assert!(SloSpec::paper_table3("x", "y").is_none());
    }
}
