//! Configuration: model architectures, device rooflines, SLOs, cluster
//! layouts. JSON round-trip so experiments are driven by config files.
//!
//! The three evaluated models carry their *real* architecture dims — the
//! cost model (and therefore every reproduced figure) depends on the true
//! per-stage FLOP/byte ratios of LLaVA-1.5-7B, LLaVA-NeXT-7B and
//! Qwen2-VL-7B, not the tiny executable VLM (which only the real-execution
//! path uses).

pub mod controller;
pub mod slo;
pub mod supervisor;

pub use controller::ControllerConfig;
pub use slo::SloSpec;
pub use supervisor::SupervisorConfig;

use crate::util::json::Json;
use crate::vision::ImageTokenRule;

/// Transformer stack dims (either the LM or the vision tower).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StackSpec {
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// KV heads (GQA); == heads for MHA.
    pub kv_heads: usize,
    pub ffn: usize,
    /// SwiGLU-style gated FFN (3 weight matrices, LLaMA/Qwen LMs) vs the
    /// plain 2-matrix MLP of ViT towers. Affects parameter/weight-byte
    /// accounting (decode is weight-bandwidth bound, so this matters).
    pub gated_ffn: bool,
}

impl StackSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }
    pub fn kv_hidden(&self) -> usize {
        self.kv_heads * self.head_dim()
    }
    /// FFN weight matrices per layer (3 for gated SwiGLU, 2 for plain MLP).
    pub fn ffn_mats(&self) -> usize {
        if self.gated_ffn {
            3
        } else {
            2
        }
    }
    /// Approximate parameter count of the stack (attention + FFN blocks).
    pub fn params(&self) -> usize {
        let attn = self.hidden * self.hidden * 2
            + self.hidden * self.kv_hidden() * 2;
        let ffn = self.ffn_mats() * self.hidden * self.ffn;
        self.layers * (attn + ffn)
    }
}

/// A full multimodal model: vision tower + projector + language model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub lm: StackSpec,
    pub vocab: usize,
    pub vision: StackSpec,
    /// Vision tower sequence length per image tile (patches + cls).
    pub vision_seq: usize,
    pub image_rule: ImageTokenRule,
    /// Bytes per element (fp16 = 2, matching the paper's setup).
    pub dtype_bytes: usize,
    /// Default image resolution assumed by workloads (w, h).
    pub default_image: (usize, usize),
}

impl ModelSpec {
    /// LLaVA-1.5-7B: Vicuna-7B LM + CLIP ViT-L/14-336, fixed 576 img tokens.
    pub fn llava15_7b() -> ModelSpec {
        ModelSpec {
            name: "llava-1.5-7b".into(),
            lm: StackSpec { layers: 32, hidden: 4096, heads: 32, kv_heads: 32, ffn: 11008, gated_ffn: true },
            vocab: 32000,
            vision: StackSpec { layers: 24, hidden: 1024, heads: 16, kv_heads: 16, ffn: 4096, gated_ffn: false },
            vision_seq: 577,
            image_rule: ImageTokenRule::LlavaFixed { tokens: 576 },
            dtype_bytes: 2,
            default_image: (336, 336),
        }
    }

    /// LLaVA-NeXT-7B: same backbone, AnyRes tiling (up to 5x image tokens).
    pub fn llava_next_7b() -> ModelSpec {
        ModelSpec {
            name: "llava-next-7b".into(),
            image_rule: ImageTokenRule::LlavaNextAnyRes { base: 576, max_tiles: 4 },
            default_image: (672, 672),
            ..ModelSpec::llava15_7b()
        }
    }

    /// Qwen2-VL-7B: GQA LM (4 KV heads) + 675M ViT, dynamic-resolution
    /// patch merging.
    pub fn qwen2_vl_7b() -> ModelSpec {
        ModelSpec {
            name: "qwen2-vl-7b".into(),
            lm: StackSpec { layers: 28, hidden: 3584, heads: 28, kv_heads: 4, ffn: 18944, gated_ffn: true },
            vocab: 152064,
            vision: StackSpec { layers: 32, hidden: 1280, heads: 16, kv_heads: 16, ffn: 5120, gated_ffn: false },
            vision_seq: 1036, // (28*2)^2/... effective per-tile ViT sequence
            image_rule: ImageTokenRule::Qwen2Dynamic {
                patch: 28,
                merge: 2,
                min_tokens: 64,
                max_tokens: 1280,
            },
            dtype_bytes: 2,
            default_image: (1092, 1092),
        }
    }

    pub fn by_name(name: &str) -> Option<ModelSpec> {
        match name {
            "llava-1.5-7b" => Some(ModelSpec::llava15_7b()),
            "llava-next-7b" => Some(ModelSpec::llava_next_7b()),
            "qwen2-vl-7b" => Some(ModelSpec::qwen2_vl_7b()),
            _ => None,
        }
    }

    pub const ALL_NAMES: [&'static str; 3] =
        ["llava-1.5-7b", "llava-next-7b", "qwen2-vl-7b"];

    /// LM params incl. embeddings + lm_head.
    pub fn lm_params(&self) -> usize {
        self.lm.params() + 2 * self.vocab * self.lm.hidden
    }
    pub fn vision_params(&self) -> usize {
        // + patch embed and projector (approximate)
        self.vision.params() + self.vision.hidden * self.lm.hidden
    }
    /// Tokens an image of the default resolution contributes to the LM.
    pub fn tokens_per_image(&self) -> usize {
        self.image_rule
            .tokens_for(self.default_image.0, self.default_image.1)
    }
}

/// Device roofline (defaults = one NVIDIA H800 SXM).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceSpec {
    /// Peak dense fp16 tensor FLOP/s.
    pub peak_flops: f64,
    /// Peak HBM bandwidth, bytes/s.
    pub peak_bw: f64,
    /// Achievable fraction of peak FLOPs (large-GEMM MFU).
    pub mfu: f64,
    /// Achievable fraction of peak bandwidth.
    pub mem_eff: f64,
    /// Fixed per-batch-iteration overhead, seconds (eager-mode kernel
    /// launches; the paper runs vLLM eager with CUDA graphs off).
    pub iter_overhead: f64,
    /// HBM capacity available for caches after weights, bytes.
    pub hbm_capacity: f64,
    /// Intra-node NVLink bandwidth, bytes/s (H800: 400 GB/s).
    pub nvlink_bw: f64,
    /// CUDA-IPC-style copy latency floor, seconds.
    pub ipc_latency: f64,
    /// NCCL collective latency floor, seconds.
    pub nccl_latency: f64,
}

impl Default for DeviceSpec {
    fn default() -> Self {
        DeviceSpec::h800()
    }
}

impl DeviceSpec {
    pub fn h800() -> DeviceSpec {
        DeviceSpec {
            peak_flops: 989e12,
            peak_bw: 3.35e12,
            mfu: 0.55,
            mem_eff: 0.85,
            iter_overhead: 300e-6,
            hbm_capacity: 80e9,
            nvlink_bw: 400e9,
            ipc_latency: 20e-6,
            nccl_latency: 60e-6,
        }
    }

    pub fn effective_flops(&self) -> f64 {
        self.peak_flops * self.mfu
    }
    pub fn effective_bw(&self) -> f64 {
        self.peak_bw * self.mem_eff
    }
}

// ------------------------------------------------------------ JSON round-trip

impl ModelSpec {
    pub fn to_json(&self) -> Json {
        let rule = match self.image_rule {
            ImageTokenRule::LlavaFixed { tokens } => Json::obj(vec![
                ("kind", Json::str("fixed")),
                ("tokens", Json::num(tokens as f64)),
            ]),
            ImageTokenRule::LlavaNextAnyRes { base, max_tiles } => Json::obj(vec![
                ("kind", Json::str("anyres")),
                ("base", Json::num(base as f64)),
                ("max_tiles", Json::num(max_tiles as f64)),
            ]),
            ImageTokenRule::Qwen2Dynamic { patch, merge, min_tokens, max_tokens } => Json::obj(vec![
                ("kind", Json::str("dynamic")),
                ("patch", Json::num(patch as f64)),
                ("merge", Json::num(merge as f64)),
                ("min_tokens", Json::num(min_tokens as f64)),
                ("max_tokens", Json::num(max_tokens as f64)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("lm", stack_json(&self.lm)),
            ("vocab", Json::num(self.vocab as f64)),
            ("vision", stack_json(&self.vision)),
            ("vision_seq", Json::num(self.vision_seq as f64)),
            ("image_rule", rule),
            ("dtype_bytes", Json::num(self.dtype_bytes as f64)),
            (
                "default_image",
                Json::arr([
                    Json::num(self.default_image.0 as f64),
                    Json::num(self.default_image.1 as f64),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> anyhow::Result<ModelSpec> {
        let rule_j = j.get("image_rule").ok_or_else(|| anyhow::anyhow!("missing image_rule"))?;
        let image_rule = match rule_j.req_str("kind")? {
            "fixed" => ImageTokenRule::LlavaFixed { tokens: rule_j.req_usize("tokens")? },
            "anyres" => ImageTokenRule::LlavaNextAnyRes {
                base: rule_j.req_usize("base")?,
                max_tiles: rule_j.req_usize("max_tiles")?,
            },
            "dynamic" => ImageTokenRule::Qwen2Dynamic {
                patch: rule_j.req_usize("patch")?,
                merge: rule_j.req_usize("merge")?,
                min_tokens: rule_j.req_usize("min_tokens")?,
                max_tokens: rule_j.req_usize("max_tokens")?,
            },
            k => anyhow::bail!("unknown image rule kind `{k}`"),
        };
        let img = j
            .get("default_image")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing default_image"))?;
        Ok(ModelSpec {
            name: j.req_str("name")?.to_string(),
            lm: stack_from_json(j.get("lm").ok_or_else(|| anyhow::anyhow!("missing lm"))?)?,
            vocab: j.req_usize("vocab")?,
            vision: stack_from_json(
                j.get("vision").ok_or_else(|| anyhow::anyhow!("missing vision"))?,
            )?,
            vision_seq: j.req_usize("vision_seq")?,
            image_rule,
            dtype_bytes: j.req_usize("dtype_bytes")?,
            default_image: (
                img[0].as_usize().unwrap_or(336),
                img[1].as_usize().unwrap_or(336),
            ),
        })
    }
}

fn stack_json(s: &StackSpec) -> Json {
    Json::obj(vec![
        ("layers", Json::num(s.layers as f64)),
        ("hidden", Json::num(s.hidden as f64)),
        ("heads", Json::num(s.heads as f64)),
        ("kv_heads", Json::num(s.kv_heads as f64)),
        ("ffn", Json::num(s.ffn as f64)),
        ("gated_ffn", Json::Bool(s.gated_ffn)),
    ])
}

fn stack_from_json(j: &Json) -> anyhow::Result<StackSpec> {
    Ok(StackSpec {
        layers: j.req_usize("layers")?,
        hidden: j.req_usize("hidden")?,
        heads: j.req_usize("heads")?,
        kv_heads: j.req_usize("kv_heads")?,
        ffn: j.req_usize("ffn")?,
        gated_ffn: j.get("gated_ffn").and_then(Json::as_bool).unwrap_or(false),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llava15_param_count_near_7b() {
        let m = ModelSpec::llava15_7b();
        let p = m.lm_params() as f64;
        assert!((6.0e9..8.0e9).contains(&p), "lm params = {p}");
        let v = m.vision_params() as f64;
        assert!((2.0e8..4.5e8).contains(&v), "vision params = {v}");
    }

    #[test]
    fn qwen2_gqa_kv_hidden() {
        let m = ModelSpec::qwen2_vl_7b();
        assert_eq!(m.lm.head_dim(), 128);
        assert_eq!(m.lm.kv_hidden(), 512); // 4 kv heads * 128
    }

    #[test]
    fn tokens_per_image_ordering() {
        // NeXT's AnyRes must produce more tokens than 1.5's fixed 576 (§5.1)
        let t15 = ModelSpec::llava15_7b().tokens_per_image();
        let tnext = ModelSpec::llava_next_7b().tokens_per_image();
        assert_eq!(t15, 576);
        assert!(tnext > t15, "next={tnext}");
    }

    #[test]
    fn by_name_roundtrip() {
        for name in ModelSpec::ALL_NAMES {
            assert_eq!(ModelSpec::by_name(name).unwrap().name, name);
        }
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn json_roundtrip_all_models() {
        for name in ModelSpec::ALL_NAMES {
            let m = ModelSpec::by_name(name).unwrap();
            let j = m.to_json();
            let m2 = ModelSpec::from_json(&crate::util::json::parse(&j.to_string()).unwrap())
                .unwrap();
            assert_eq!(m, m2);
        }
    }

    #[test]
    fn h800_roofline_sanity() {
        let d = DeviceSpec::h800();
        // ridge point (flops/byte where compute == memory time)
        let ridge = d.effective_flops() / d.effective_bw();
        assert!((100.0..250.0).contains(&ridge), "ridge = {ridge}");
    }
}
