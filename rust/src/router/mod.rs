//! Request routing / load balancing across instances (paper §4: "The
//! scheduler performs load balancing based on request types, dispatching
//! them to the corresponding Encode or Prefill instances"; §4.3: the
//! Migrate Scheduler "can adopt strategies such as round-robin or random
//! selection").

use crate::util::rng::Rng;

/// Load-balancing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    Random,
}

/// Stateful router: picks one of N candidates given their current loads.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    rr: usize,
    rng: Rng,
}

impl Router {
    pub fn new(policy: RoutePolicy, seed: u64) -> Self {
        Router { policy, rr: 0, rng: Rng::new(seed) }
    }

    /// Pick an index into `loads` (lower load = more attractive). A
    /// non-finite load (infinity/NaN) marks a candidate as *ineligible* —
    /// e.g. an instance mid-drain during a role reconfiguration — and it
    /// is never picked under any policy. Returns None when `loads` is
    /// empty or no candidate is eligible.
    pub fn pick(&mut self, loads: &[f64]) -> Option<usize> {
        let eligible: Vec<usize> = loads
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_finite())
            .map(|(i, _)| i)
            .collect();
        if eligible.is_empty() {
            return None;
        }
        Some(match self.policy {
            RoutePolicy::RoundRobin => {
                let i = eligible[self.rr % eligible.len()];
                self.rr += 1;
                i
            }
            RoutePolicy::Random => eligible[self.rng.below(eligible.len())],
            RoutePolicy::LeastLoaded => {
                let mut best = eligible[0];
                for &i in &eligible {
                    if loads[i] < loads[best] {
                        best = i;
                    }
                }
                best
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 0);
        let loads = [0.0, 0.0, 0.0];
        let picks: Vec<_> = (0..6).map(|_| r.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(r.pick(&[0.5, 1.0, 0.5]), Some(0)); // first min wins
    }

    #[test]
    fn random_covers_all() {
        let mut r = Router::new(RoutePolicy::Random, 42);
        let loads = [0.0; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.pick(&loads).unwrap()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn empty_candidates() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[]), None);
    }

    #[test]
    fn draining_instances_are_ineligible() {
        // regression: a mid-drain instance advertises load = infinity and
        // must never receive new work, under any policy
        let inf = f64::INFINITY;
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[inf, 1.0, 2.0]), Some(1));
        assert_eq!(r.pick(&[3.0, inf, 2.0]), Some(2));

        let mut rr = Router::new(RoutePolicy::RoundRobin, 0);
        let picks: Vec<_> = (0..4).map(|_| rr.pick(&[0.0, inf, 0.0]).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "round-robin skips the draining slot");

        let mut rnd = Router::new(RoutePolicy::Random, 42);
        for _ in 0..100 {
            assert_ne!(rnd.pick(&[0.0, inf, 0.0]), Some(1));
        }
    }

    #[test]
    fn all_draining_yields_none() {
        let inf = f64::INFINITY;
        for policy in [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin, RoutePolicy::Random] {
            let mut r = Router::new(policy, 7);
            assert_eq!(r.pick(&[inf, inf]), None, "{policy:?}");
        }
        // NaN is also ineligible
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[f64::NAN, 1.0]), Some(1));
        assert_eq!(r.pick(&[f64::NAN]), None);
    }
}
