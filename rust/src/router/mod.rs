//! Request routing / load balancing across instances (paper §4: "The
//! scheduler performs load balancing based on request types, dispatching
//! them to the corresponding Encode or Prefill instances"; §4.3: the
//! Migrate Scheduler "can adopt strategies such as round-robin or random
//! selection").
//!
//! On top of the load policies sits **cache-affinity scoring**
//! ([`Router::pick_affinity`]): a candidate whose content-addressed cache
//! already holds the request's image embedding or KV prefix is preferred
//! over a merely idle one — work it would otherwise recompute (and bytes
//! a migration would otherwise transfer) simply don't happen. Load breaks
//! ties, and with no affinity anywhere the configured policy decides.

use crate::util::rng::Rng;

/// Load-balancing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    Random,
}

/// Stateful router: picks one of N candidates given their current loads.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    rr: usize,
    rng: Rng,
}

impl Router {
    pub fn new(policy: RoutePolicy, seed: u64) -> Self {
        Router { policy, rr: 0, rng: Rng::new(seed) }
    }

    /// Pick an index into `loads` (lower load = more attractive). A
    /// non-finite load (infinity/NaN) marks a candidate as *ineligible* —
    /// e.g. an instance mid-drain during a role reconfiguration — and it
    /// is never picked under any policy. Returns None when `loads` is
    /// empty or no candidate is eligible.
    ///
    /// Allocation-free: this runs once per routed request and once per
    /// migration target pick, so it must never heap-allocate.
    // invlint: hot-path
    pub fn pick(&mut self, loads: &[f64]) -> Option<usize> {
        let eligible = loads.iter().filter(|l| l.is_finite()).count();
        if eligible == 0 {
            return None;
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let k = self.rr % eligible;
                self.rr += 1;
                Self::nth_eligible(loads, k)
            }
            RoutePolicy::Random => Self::nth_eligible(loads, self.rng.below(eligible)),
            RoutePolicy::LeastLoaded => {
                let mut best: Option<usize> = None;
                for (i, l) in loads.iter().enumerate() {
                    if !l.is_finite() {
                        continue;
                    }
                    // strict `<` keeps the first minimum, matching the old
                    // collect-then-scan behaviour exactly
                    if best.map_or(true, |b| *l < loads[b]) {
                        best = Some(i);
                    }
                }
                best
            }
        }
    }

    /// Index of the k-th (0-based) finite-load candidate.
    // invlint: hot-path
    fn nth_eligible(loads: &[f64], k: usize) -> Option<usize> {
        loads
            .iter()
            .enumerate()
            .filter(|(_, l)| l.is_finite())
            .nth(k)
            .map(|(i, _)| i)
    }

    /// Build a gated load vector: eligible slots get load `0.0`,
    /// ineligible ones `f64::INFINITY` (which [`Router::pick`] never
    /// selects under any policy). One gating idiom shared by the drain
    /// path (mid-reconfiguration instances) and the fault path
    /// (supervisor-flagged dead instances) — ineligibility is always
    /// expressed as a non-finite load, never as a separate code path.
    pub fn gated_loads(n: usize, eligible: impl Fn(usize) -> bool) -> Vec<f64> {
        (0..n).map(|i| if eligible(i) { 0.0 } else { f64::INFINITY }).collect()
    }

    /// Load ceiling used by [`Router::pick_affinity`]: an affinity
    /// candidate only wins while its load stays within this band of the
    /// least-loaded eligible candidate (a cached copy is worth a
    /// moderately longer queue, not an unbounded one). Exposed so callers
    /// that pre-filter candidates (the simulator's affinity early-exit)
    /// apply the exact same rule.
    pub fn affinity_load_cap(min_load: f64) -> f64 {
        4.0 + 2.0 * min_load
    }

    /// Cache-affinity pick: among eligible candidates (finite load),
    /// prefer the one whose cache already holds the most of this request
    /// (`affinity[i]` = reusable tokens/bytes on candidate i). Load breaks
    /// affinity ties, and — to stop all shared-content traffic herding
    /// onto one instance past its capacity — an affinity candidate only
    /// wins while its load stays within a slack band of the least-loaded
    /// eligible candidate; beyond that, recomputing is cheaper than
    /// queueing and the pick degrades to the plain load policy. With zero
    /// affinity everywhere this is exactly [`Router::pick`]. `affinity`
    /// must be at least as long as `loads`.
    // invlint: hot-path
    pub fn pick_affinity(&mut self, loads: &[f64], affinity: &[f64]) -> Option<usize> {
        debug_assert!(affinity.len() >= loads.len(), "affinity per candidate");
        let min_load = loads
            .iter()
            .copied()
            .filter(|l| l.is_finite())
            .fold(f64::INFINITY, f64::min);
        let load_cap = Router::affinity_load_cap(min_load);
        let mut best: Option<usize> = None;
        for (i, l) in loads.iter().enumerate() {
            if !l.is_finite() || affinity[i] <= 0.0 || *l > load_cap {
                continue;
            }
            best = match best {
                Some(b)
                    if affinity[b] > affinity[i]
                        || (affinity[b] == affinity[i] && loads[b] <= loads[i]) =>
                {
                    Some(b)
                }
                _ => Some(i),
            };
        }
        match best {
            Some(b) => Some(b),
            None => self.pick(loads),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 0);
        let loads = [0.0, 0.0, 0.0];
        let picks: Vec<_> = (0..6).map(|_| r.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(r.pick(&[0.5, 1.0, 0.5]), Some(0)); // first min wins
    }

    #[test]
    fn random_covers_all() {
        let mut r = Router::new(RoutePolicy::Random, 42);
        let loads = [0.0; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.pick(&loads).unwrap()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn empty_candidates() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[]), None);
    }

    #[test]
    fn draining_instances_are_ineligible() {
        // regression: a mid-drain instance advertises load = infinity and
        // must never receive new work, under any policy
        let inf = f64::INFINITY;
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[inf, 1.0, 2.0]), Some(1));
        assert_eq!(r.pick(&[3.0, inf, 2.0]), Some(2));

        let mut rr = Router::new(RoutePolicy::RoundRobin, 0);
        let picks: Vec<_> = (0..4).map(|_| rr.pick(&[0.0, inf, 0.0]).unwrap()).collect();
        assert_eq!(picks, vec![0, 2, 0, 2], "round-robin skips the draining slot");

        let mut rnd = Router::new(RoutePolicy::Random, 42);
        for _ in 0..100 {
            assert_ne!(rnd.pick(&[0.0, inf, 0.0]), Some(1));
        }
    }

    #[test]
    fn affinity_beats_load_but_not_eligibility() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        // candidate 2 holds cached content: preferred over the idle 0
        assert_eq!(r.pick_affinity(&[0.0, 5.0, 3.0], &[0.0, 0.0, 64.0]), Some(2));
        // highest affinity wins; load breaks affinity ties
        assert_eq!(r.pick_affinity(&[1.0, 2.0, 3.0], &[64.0, 576.0, 576.0]), Some(1));
        // a draining (infinite-load) candidate is never picked, cached or not
        let inf = f64::INFINITY;
        assert_eq!(r.pick_affinity(&[0.0, inf], &[0.0, 576.0]), Some(0));
        // no affinity anywhere -> plain policy pick
        assert_eq!(r.pick_affinity(&[3.0, 1.0, 2.0], &[0.0, 0.0, 0.0]), Some(1));
        // nothing eligible -> None
        assert_eq!(r.pick_affinity(&[inf, inf], &[1.0, 2.0]), None);
    }

    #[test]
    fn affinity_does_not_herd_onto_an_overloaded_instance() {
        // the instance holding the hot content is saturated: recomputing
        // on an idle peer beats queueing behind 50 requests
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(
            r.pick_affinity(&[50.0, 0.0, 0.5], &[576.0, 0.0, 0.0]),
            Some(1),
            "fall back to load policy when the cached instance is overloaded"
        );
        // ...but a moderate queue is worth the cache hit
        assert_eq!(r.pick_affinity(&[3.0, 0.0, 0.5], &[576.0, 0.0, 0.0]), Some(0));
    }

    #[test]
    fn gated_loads_mark_ineligible_slots_non_finite() {
        let dead = [false, true, false, true];
        let loads = Router::gated_loads(4, |i| !dead[i]);
        assert_eq!(loads.len(), 4);
        assert!(loads[0].is_finite() && loads[2].is_finite());
        assert!(!loads[1].is_finite() && !loads[3].is_finite());
        let mut r = Router::new(RoutePolicy::RoundRobin, 0);
        for _ in 0..8 {
            let p = r.pick(&loads).unwrap();
            assert!(p == 0 || p == 2, "dead slots never picked");
        }
    }

    #[test]
    fn all_draining_yields_none() {
        let inf = f64::INFINITY;
        for policy in [RoutePolicy::LeastLoaded, RoutePolicy::RoundRobin, RoutePolicy::Random] {
            let mut r = Router::new(policy, 7);
            assert_eq!(r.pick(&[inf, inf]), None, "{policy:?}");
        }
        // NaN is also ineligible
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[f64::NAN, 1.0]), Some(1));
        assert_eq!(r.pick(&[f64::NAN]), None);
    }
}
