//! Request routing / load balancing across instances (paper §4: "The
//! scheduler performs load balancing based on request types, dispatching
//! them to the corresponding Encode or Prefill instances"; §4.3: the
//! Migrate Scheduler "can adopt strategies such as round-robin or random
//! selection").

use crate::util::rng::Rng;

/// Load-balancing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
    Random,
}

/// Stateful router: picks one of N candidates given their current loads.
#[derive(Debug)]
pub struct Router {
    policy: RoutePolicy,
    rr: usize,
    rng: Rng,
}

impl Router {
    pub fn new(policy: RoutePolicy, seed: u64) -> Self {
        Router { policy, rr: 0, rng: Rng::new(seed) }
    }

    /// Pick an index into `loads` (lower load = more attractive). Returns
    /// None when `loads` is empty.
    pub fn pick(&mut self, loads: &[f64]) -> Option<usize> {
        if loads.is_empty() {
            return None;
        }
        Some(match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr % loads.len();
                self.rr += 1;
                i
            }
            RoutePolicy::Random => self.rng.below(loads.len()),
            RoutePolicy::LeastLoaded => {
                let mut best = 0;
                for (i, &l) in loads.iter().enumerate() {
                    if l < loads[best] {
                        best = i;
                    }
                }
                best
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin, 0);
        let loads = [0.0, 0.0, 0.0];
        let picks: Vec<_> = (0..6).map(|_| r.pick(&loads).unwrap()).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn least_loaded_picks_min() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[3.0, 1.0, 2.0]), Some(1));
        assert_eq!(r.pick(&[0.5, 1.0, 0.5]), Some(0)); // first min wins
    }

    #[test]
    fn random_covers_all() {
        let mut r = Router::new(RoutePolicy::Random, 42);
        let loads = [0.0; 4];
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[r.pick(&loads).unwrap()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn empty_candidates() {
        let mut r = Router::new(RoutePolicy::LeastLoaded, 0);
        assert_eq!(r.pick(&[]), None);
    }
}
