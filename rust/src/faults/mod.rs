//! Deterministic fault injection: failure as a first-class, seeded,
//! schedulable event (PR 9).
//!
//! The simulator consumes a [`FaultPlan`] — a seeded schedule of instance
//! crashes and recoveries, cluster-wide link degradation windows, and
//! per-instance straggler slowdown factors. The engine applies due fault
//! events at window barriers only (single-threaded, canonical order), so
//! a faulty run's [`crate::simulator::engine::SimResult::digest`] is
//! bit-identical for any shard count — the same contract every other
//! cluster-global effect (routing, controller ticks, migration retargets)
//! already rides.
//!
//! The real plane consumes [`RetryPolicy`] (bounded exponential backoff
//! for message sends and batch retries) together with
//! [`crate::config::SupervisorConfig`] (heartbeat liveness scanning).
//!
//! An empty plan is the default and must be behaviourally invisible: the
//! golden-determinism digests pin that property.

use crate::scheduler::StageMask;
use crate::core::Stage;

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The instance dies: its current batch is lost, queues are drained
    /// and salvaged (re-routed to surviving instances, resuming at the
    /// longest cached prefix a survivor holds), its caches are dropped,
    /// and the content directory retracts every advertisement it made.
    Crash { instance: usize },
    /// The instance rejoins with the role it held when it crashed
    /// (fresh, empty caches). Parked requests waiting for this stage are
    /// retried.
    Recover { instance: usize },
    /// Cluster-wide link degradation: migration-transfer and cache-fetch
    /// durations multiply by `factor` from this point on (`1.0` restores
    /// full speed — a degradation *window* is two events).
    LinkDegrade { factor: f64 },
    /// Per-instance compute slowdown: this instance's batch durations
    /// multiply by `factor` from this point on (`1.0` restores it).
    Straggler { instance: usize, factor: f64 },
}

/// A fault scheduled at simulated time `t` (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    pub kind: FaultKind,
}

/// A full fault schedule for one simulation run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
    /// When a salvaged request momentarily has no live instance serving
    /// its stage, park it and retry on the next recovery (`true`, the
    /// default) instead of counting it lost immediately (`false`).
    pub retry: bool,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan { events: Vec::new(), retry: true }
    }
}

impl FaultPlan {
    /// No faults scheduled — the engine must behave exactly as if the
    /// fault subsystem did not exist.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// The schedule in canonical application order: ascending time,
    /// crashes before recoveries at equal times (so a crash/recover pair
    /// landing on the same barrier nets out to a restart), instance id
    /// last. Deterministic regardless of how the plan was assembled.
    pub fn sorted_events(&self) -> Vec<FaultEvent> {
        let mut evs = self.events.clone();
        evs.sort_by(|a, b| {
            a.t.total_cmp(&b.t)
                .then(kind_rank(&a.kind).cmp(&kind_rank(&b.kind)))
                .then(kind_instance(&a.kind).cmp(&kind_instance(&b.kind)))
        });
        evs
    }

    /// Seeded per-stage-role chaos schedule: crash one instance serving
    /// each of Encode / Prefill / Decode (staggered by `spacing` starting
    /// at `t0`), recovering each after `down` seconds (`down <= 0` means
    /// no recovery). The seeded pick never removes the last live server
    /// of any stage, even across overlapping downtime windows — the
    /// survivor guarantee the `lost_requests == 0` property test leans
    /// on. Stages with no crashable candidate are skipped.
    pub fn per_role_crashes(
        masks: &[StageMask],
        t0: f64,
        spacing: f64,
        down: f64,
        seed: u64,
    ) -> FaultPlan {
        let mut state = seed ^ 0x9e3779b97f4a7c15;
        let mut crashed: Vec<usize> = Vec::new();
        let mut events = Vec::new();
        let stages = [Stage::Encode, Stage::Prefill, Stage::Decode];
        for (k, &stage) in stages.iter().enumerate() {
            let candidates: Vec<usize> = (0..masks.len())
                .filter(|&i| masks[i].serves(stage) && !crashed.contains(&i))
                .collect();
            if candidates.is_empty() {
                continue;
            }
            let start = (splitmix64(&mut state) as usize) % candidates.len();
            let pick = (0..candidates.len())
                .map(|j| candidates[(start + j) % candidates.len()])
                .find(|&c| survivors_remain(masks, &crashed, c));
            let Some(inst) = pick else { continue };
            crashed.push(inst);
            let t = t0 + k as f64 * spacing;
            events.push(FaultEvent { t, kind: FaultKind::Crash { instance: inst } });
            if down > 0.0 {
                events
                    .push(FaultEvent { t: t + down, kind: FaultKind::Recover { instance: inst } });
            }
        }
        FaultPlan { events, retry: true }
    }
}

/// Canonical same-time ordering: crashes apply before recoveries.
fn kind_rank(k: &FaultKind) -> u8 {
    match k {
        FaultKind::Crash { .. } => 0,
        FaultKind::Recover { .. } => 1,
        FaultKind::LinkDegrade { .. } => 2,
        FaultKind::Straggler { .. } => 3,
    }
}

fn kind_instance(k: &FaultKind) -> usize {
    match k {
        FaultKind::Crash { instance }
        | FaultKind::Recover { instance }
        | FaultKind::Straggler { instance, .. } => *instance,
        FaultKind::LinkDegrade { .. } => 0,
    }
}

/// Would crashing `next` (on top of `crashed`) still leave every stage
/// with at least one live server? Conservative: treats every crash window
/// as overlapping.
fn survivors_remain(masks: &[StageMask], crashed: &[usize], next: usize) -> bool {
    [Stage::Encode, Stage::Prefill, Stage::Decode].iter().all(|&s| {
        (0..masks.len())
            .any(|i| i != next && !crashed.contains(&i) && masks[i].serves(s))
    })
}

/// Sebastiano Vigna's splitmix64 — the crate's seeded-generator idiom
/// (no external RNG dependency, identical streams on every platform).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Bounded exponential backoff for the real plane: message sends that
/// fail (instance channel closed) and batch steps that error retry at
/// most `max_attempts` times, sleeping `delay_ms(attempt)` between tries,
/// before the request is dead-lettered.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    pub max_attempts: usize,
    pub base_delay_ms: u64,
    pub backoff: f64,
    pub max_delay_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_attempts: 3, base_delay_ms: 2, backoff: 2.0, max_delay_ms: 50 }
    }
}

impl RetryPolicy {
    /// Delay before retry number `attempt` (0-based): capped exponential.
    pub fn delay_ms(&self, attempt: usize) -> u64 {
        let d = self.base_delay_ms as f64 * self.backoff.powi(attempt.min(63) as i32);
        (d.min(self.max_delay_ms as f64)) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_default_and_retries() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(p.retry);
    }

    #[test]
    fn per_role_crashes_is_seed_deterministic() {
        let masks = [StageMask::E, StageMask::E, StageMask::P, StageMask::P, StageMask::D,
            StageMask::D, StageMask::D, StageMask::D];
        let a = FaultPlan::per_role_crashes(&masks, 1.0, 0.5, 2.0, 7);
        let b = FaultPlan::per_role_crashes(&masks, 1.0, 0.5, 2.0, 7);
        assert_eq!(a, b);
        // one crash + one recover per stage role
        assert_eq!(a.events.len(), 6);
    }

    #[test]
    fn per_role_crashes_always_leaves_a_survivor_per_stage() {
        let shapes: [&[StageMask]; 3] = [
            &[StageMask::E, StageMask::E, StageMask::P, StageMask::P, StageMask::D, StageMask::D],
            &[StageMask::EPD, StageMask::EPD, StageMask::EPD],
            &[StageMask::E, StageMask::EP, StageMask::PD, StageMask::D],
        ];
        for masks in shapes {
            for seed in 0..32u64 {
                let plan = FaultPlan::per_role_crashes(masks, 0.5, 0.25, 1.0, seed);
                let crashed: Vec<usize> = plan
                    .events
                    .iter()
                    .filter_map(|e| match e.kind {
                        FaultKind::Crash { instance } => Some(instance),
                        _ => None,
                    })
                    .collect();
                for s in [Stage::Encode, Stage::Prefill, Stage::Decode] {
                    let alive = (0..masks.len())
                        .any(|i| !crashed.contains(&i) && masks[i].serves(s));
                    assert!(alive, "seed {seed}: stage {s:?} lost its last server");
                }
            }
        }
    }

    #[test]
    fn single_server_stages_are_never_crashed() {
        // 1E1P1D: crashing any instance would kill a stage outright
        let masks = [StageMask::E, StageMask::P, StageMask::D];
        for seed in 0..16u64 {
            let plan = FaultPlan::per_role_crashes(&masks, 0.5, 0.25, 1.0, seed);
            assert!(plan.is_empty(), "seed {seed} crashed a sole server");
        }
    }

    #[test]
    fn sorted_events_apply_crashes_before_recoveries() {
        let plan = FaultPlan {
            events: vec![
                FaultEvent { t: 1.0, kind: FaultKind::Recover { instance: 0 } },
                FaultEvent { t: 1.0, kind: FaultKind::Crash { instance: 1 } },
                FaultEvent { t: 0.5, kind: FaultKind::Straggler { instance: 2, factor: 2.0 } },
            ],
            retry: true,
        };
        let evs = plan.sorted_events();
        assert!(matches!(evs[0].kind, FaultKind::Straggler { .. }));
        assert!(matches!(evs[1].kind, FaultKind::Crash { .. }));
        assert!(matches!(evs[2].kind, FaultKind::Recover { .. }));
    }

    #[test]
    fn retry_delay_grows_and_caps() {
        let p = RetryPolicy::default();
        assert_eq!(p.delay_ms(0), 2);
        assert_eq!(p.delay_ms(1), 4);
        assert_eq!(p.delay_ms(2), 8);
        assert!(p.delay_ms(10) <= p.max_delay_ms);
        for a in 0..12 {
            assert!(p.delay_ms(a + 1) >= p.delay_ms(a));
        }
    }
}
