//! HydraInfer launcher.
//!
//! Subcommands:
//!   serve     — boot a real disaggregated cluster over the AOT artifacts
//!               and expose the OpenAI-style HTTP API
//!   simulate  — run the roofline-calibrated cluster simulator on a
//!               dataset workload and print serving metrics
//!   plan      — hybrid EPD disaggregation search (§4.4): best method +
//!               node ratio for a workload and SLO
//!   budgets   — profile Algorithm 1's token/image budgets for a TPOT SLO
//!   workload  — generate + save a reproducible request trace
//!
//! Examples:
//!   hydrainfer serve --cluster 1E1P2D --port 8077
//!   hydrainfer simulate --model llava-1.5-7b --dataset textcaps \
//!       --cluster 1E3P4D --rate 8 --requests 200
//!   hydrainfer plan --model llava-next-7b --dataset pope --gpus 8

use anyhow::{anyhow, Result};

use hydrainfer::api::ApiServer;
use hydrainfer::config::{DeviceSpec, ModelSpec, SloSpec};
use hydrainfer::instance::RealCluster;
use hydrainfer::metrics::goodput_search;
use hydrainfer::planner::{plan, PlannerConfig};
use hydrainfer::scheduler::{
    compute_image_budget, compute_token_budget, BudgetProfile, Policy,
};
use hydrainfer::simulator::{simulate, ClusterSpec, SimConfig};
use hydrainfer::util::cli::Args;
use hydrainfer::workload::{Dataset, PoissonGenerator, Trace};

fn main() {
    let args = Args::from_env(&["help", "verbose", "goodput", "elastic", "chaos"]);
    if args.flag("verbose") {
        hydrainfer::util::logging::set_level(hydrainfer::util::logging::Level::Debug);
    }
    let result = match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("plan") => cmd_plan(&args),
        Some("budgets") => cmd_budgets(&args),
        Some("workload") => cmd_workload(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "hydrainfer — hybrid EPD disaggregated MLLM serving (paper reproduction)\n\
         \n\
         USAGE: hydrainfer <serve|simulate|plan|budgets|workload> [options]\n\
         \n\
         serve     --cluster 1E1P2D --port 8077 --artifacts artifacts [--elastic]\n\
         simulate  --model llava-1.5-7b --dataset textcaps --cluster 1E3P4D\n\
         \x20         --rate 8 --requests 200 --policy stage-level [--goodput]\n\
         \x20         [--elastic]  (online role reconfiguration)\n\
         \x20         [--trace-out trace.json]  (Perfetto flight-recorder dump)\n\
         \x20         [--shards 4]  (parallel event shards; digest-invariant)\n\
         \x20         [--window 0.002]  (cross-shard merge window, seconds)\n\
         \x20         [--chaos]  (seeded per-role crash/recover fault plan)\n\
         \x20         [--chaos-seed 7] [--chaos-down 1.0]  (downtime seconds;\n\
         \x20          <=0 = crashed instances stay dead)\n\
         plan      --model llava-next-7b --dataset textcaps --gpus 8\n\
         budgets   --model llava-1.5-7b --tpot 0.04\n\
         workload  --model llava-1.5-7b --dataset mme --rate 4 --n 500\n\
         \x20         --out trace.json"
    );
}

fn model_arg(args: &Args) -> Result<ModelSpec> {
    let name = args.get_or("model", "llava-1.5-7b");
    ModelSpec::by_name(name)
        .ok_or_else(|| anyhow!("unknown model `{name}` (try: {:?})", ModelSpec::ALL_NAMES))
}

fn dataset_arg(args: &Args) -> Result<Dataset> {
    let name = args.get_or("dataset", "textcaps");
    Dataset::by_name(name)
        .ok_or_else(|| anyhow!("unknown dataset `{name}` (try: {:?})", Dataset::ALL_NAMES))
}

fn policy_arg(args: &Args) -> Result<Policy> {
    let name = args.get_or("policy", "stage-level");
    Policy::by_name(name).ok_or_else(|| anyhow!("unknown policy `{name}`"))
}

fn slo_arg(args: &Args, model: &ModelSpec, dataset: &Dataset) -> Result<SloSpec> {
    let default = SloSpec::paper_table3(&model.name, dataset.name)
        .unwrap_or(SloSpec::new(0.25, 0.04));
    Ok(SloSpec::new(
        args.f64_or("ttft-slo", default.ttft)?,
        args.f64_or("tpot-slo", default.tpot)?,
    ))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let cluster = ClusterSpec::parse(args.get_or("cluster", "1E1P2D"))?;
    let artifacts = args.get_or("artifacts", "artifacts");
    let port = args.usize_or("port", 8077)?;
    let policy = policy_arg(args)?;
    let elastic = args.flag("elastic").then(hydrainfer::config::ControllerConfig::default);
    println!("loading artifacts from `{artifacts}` (compiles once, ~30s)...");
    let rc = RealCluster::start_with_controller(artifacts, &cluster, policy, elastic)?;
    let server = ApiServer::start(rc, &format!("127.0.0.1:{port}"))?;
    println!(
        "serving cluster {} on http://{}{}",
        cluster.label(),
        server.addr,
        if args.flag("elastic") { " (elastic controller on)" } else { "" }
    );
    println!("  POST /v1/completions {{\"prompt\": \"hi\", \"max_tokens\": 8, \"image\": true}}");
    println!("  GET  /health");
    println!("  GET  /status");
    println!("  GET  /metrics   (Prometheus text exposition)");
    println!("  GET  /trace     (Chrome trace-event JSON — open in Perfetto)");
    println!("Ctrl-C to stop.");
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let model = model_arg(args)?;
    let dataset = dataset_arg(args)?;
    let cluster = ClusterSpec::parse(args.get_or("cluster", "8EPD"))?;
    let policy = policy_arg(args)?;
    let slo = slo_arg(args, &model, &dataset)?;
    let rate = args.f64_or("rate", 8.0)?;
    let n = args.usize_or("requests", 200)?;
    let seed = args.usize_or("seed", 0)? as u64;

    let mut cfg = SimConfig::new(model.clone(), cluster.clone(), policy, slo);
    cfg.seed = seed;
    // --shards N: run the event engine on N parallel shards. Pure execution
    // strategy — the digest is bit-identical for any shard count.
    cfg.shards = args.usize_or("shards", 1)?.max(1);
    // --window SECONDS: override the conservative merge window (default:
    // the cost model's minimum link latency).
    cfg.window = args.f64_or("window", 0.0)?;
    if args.flag("elastic") {
        cfg.controller = Some(hydrainfer::config::ControllerConfig::default());
    }
    // --trace-out PATH: record the stage-span flight recorder and write a
    // Perfetto-loadable Chrome trace of the run (tracing never reschedules:
    // digests are bit-identical on or off)
    let trace_out = args.str_opt("trace-out").map(str::to_string);
    cfg.trace = trace_out.is_some();
    if args.flag("goodput") {
        let g = goodput_search(
            |r| {
                let gen = PoissonGenerator::new(dataset.clone(), r, seed);
                let reqs = gen.generate(&model, n);
                simulate(&cfg, &reqs).metrics.slo_attainment(slo)
            },
            0.90,
            args.f64_or("max-rate", 128.0)?,
            0.25,
        );
        println!(
            "goodput: {g:.2} req/s  (model={}, dataset={}, cluster={}, policy={}, slo {}s/{}s)",
            model.name,
            dataset.name,
            cluster.label(),
            policy.name(),
            slo.ttft,
            slo.tpot
        );
        return Ok(());
    }

    // --chaos: lace the trace with a seeded per-stage-role crash/recover
    // plan placed inside the arrival span (survivors per stage are
    // guaranteed, so retries keep lost_requests at 0). One seed pins the
    // whole scenario — trace and fault plan together.
    let reqs = if args.flag("chaos") {
        let chaos_seed = args.usize_or("chaos-seed", seed as usize)? as u64;
        let down = args.f64_or("chaos-down", 1.0)?;
        let (reqs, plan) = hydrainfer::workload::fault_laced_trace(
            &model,
            dataset.clone(),
            rate,
            n,
            chaos_seed,
            &cluster.instance_masks(),
            down,
        );
        println!(
            "chaos: {} fault events (seed {chaos_seed}, down {down}s)",
            plan.events.len()
        );
        cfg.faults = plan;
        reqs
    } else {
        PoissonGenerator::new(dataset.clone(), rate, seed).generate(&model, n)
    };
    let res = simulate(&cfg, &reqs);
    let m = &res.metrics;
    println!(
        "model={} dataset={} cluster={} policy={} rate={rate} req/s n={n}{}",
        model.name,
        dataset.name,
        cluster.label(),
        policy.name(),
        if cfg.shards > 1 { format!("  shards={}", cfg.shards) } else { String::new() }
    );
    println!(
        "  finished {}/{}  batches={}  migrations={}  dropped={}  reconfigs={}",
        m.num_finished(),
        n,
        res.batches,
        res.migrations,
        res.dropped_requests,
        res.reconfigs
    );
    // machine-parseable: the chaos-smoke CI job asserts digest equality
    // across shard counts and zero lost requests from these two lines
    println!("  digest {:016x}", res.digest());
    if res.fault_events > 0 {
        println!(
            "  faults: events={} crashes={} recovered={} lost={}",
            res.fault_events, res.crashes, res.recovered_requests, res.lost_requests
        );
    }
    let d = res.cache.directory;
    if d.publishes > 0 || d.fetches > 0 {
        println!(
            "  directory: {} publishes, {} retractions, {} queries; \
             {} fetches ({} images, {} kv tokens), {} stale",
            d.publishes,
            d.retractions,
            d.queries,
            d.fetches,
            d.fetched_images,
            d.fetched_kv_tokens,
            d.stale_fetches
        );
    }
    for ev in &res.reconfig_events {
        println!(
            "  reconfig @ {:.1}s: instance {} {} -> {}",
            ev.t,
            ev.instance,
            ev.from.label(),
            ev.to.label()
        );
    }
    println!(
        "  TTFT  mean {:.4}s  p50 {:.4}s  p90 {:.4}s  p99 {:.4}s",
        m.ttft().mean(),
        m.ttft().p50(),
        m.ttft().p90(),
        m.ttft().p99()
    );
    println!(
        "  TPOT  mean {:.4}s  p50 {:.4}s  p90 {:.4}s  p99 {:.4}s",
        m.tpot().mean(),
        m.tpot().p50(),
        m.tpot().p90(),
        m.tpot().p99()
    );
    println!(
        "  SLO attainment {:.1}%  throughput {:.2} req/s  {:.1} tok/s",
        m.slo_attainment(slo) * 100.0,
        m.throughput(),
        m.token_throughput()
    );
    println!("  phase breakdown (mean seconds/request):");
    let bd = m.phase_breakdown();
    for p in hydrainfer::core::Phase::ALL {
        println!("    {:>14}: {:.4}", p.name(), bd[p as usize]);
    }
    if let Some(path) = trace_out {
        std::fs::write(&path, res.trace_json().to_string())?;
        println!(
            "  wrote {} trace spans to {path} ({} overwritten) — load in Perfetto",
            res.trace.len(),
            res.trace_dropped
        );
    }
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let model = model_arg(args)?;
    let dataset = dataset_arg(args)?;
    let slo = slo_arg(args, &model, &dataset)?;
    let pc = PlannerConfig {
        gpus: args.usize_or("gpus", 8)?,
        sample_requests: args.usize_or("requests", 120)?,
        max_rate: args.f64_or("max-rate", 96.0)?,
        rate_tol: args.f64_or("tol", 1.0)?,
        seed: args.usize_or("seed", 0)? as u64,
        ..Default::default()
    };
    println!(
        "planning: model={} dataset={} gpus={} slo=({:.2}s, {:.3}s) ... (simulating all candidates)",
        model.name, dataset.name, pc.gpus, slo.ttft, slo.tpot
    );
    let p = plan(&model, &dataset, slo, &pc);
    println!("{:<8} {:<10} {:>10} {:>12} {:>12}", "method", "cluster", "goodput", "ttft(mean)", "tpot(mean)");
    for c in p.candidates.iter().take(args.usize_or("top", 12)?) {
        println!(
            "{:<8} {:<10} {:>10.2} {:>12.4} {:>12.4}",
            c.method.name(),
            c.cluster.label(),
            c.goodput,
            c.ttft_mean,
            c.tpot_mean
        );
    }
    let best = p.best();
    println!(
        "\nselected: {} {} (goodput {:.2} req/s)",
        best.method.name(),
        best.cluster.label(),
        best.goodput
    );
    Ok(())
}

fn cmd_budgets(args: &Args) -> Result<()> {
    let model = model_arg(args)?;
    let tpot = args.f64_or("tpot", 0.04)?;
    let device = DeviceSpec::h800();
    let profile = BudgetProfile::default();
    let tokens = compute_token_budget(&model, &device, &profile, tpot);
    let images = compute_image_budget(&model, &device, &profile, tpot);
    println!(
        "model={} TPOT SLO={tpot}s -> token budget {tokens}, image budget {images} \
         (assuming {} decodes @ ctx {})",
        model.name, profile.typical_decode_batch, profile.typical_context
    );
    Ok(())
}

fn cmd_workload(args: &Args) -> Result<()> {
    let model = model_arg(args)?;
    let dataset = dataset_arg(args)?;
    let rate = args.f64_or("rate", 4.0)?;
    let n = args.usize_or("n", 500)?;
    let seed = args.usize_or("seed", 0)? as u64;
    let out = args.get_or("out", "trace.json");
    let gen = PoissonGenerator::new(dataset.clone(), rate, seed);
    let trace = Trace::new(gen.generate(&model, n));
    trace.save(out)?;
    let s = hydrainfer::workload::summarize(&trace.requests);
    println!(
        "wrote {n} requests to {out} (rate {rate}/s): avg image tokens {:.0}, \
         prompt {:.0}, prefill {:.0}, output {:.0}",
        s.avg_image_tokens, s.avg_prompt_tokens, s.avg_prefill_tokens, s.avg_output_tokens
    );
    Ok(())
}
