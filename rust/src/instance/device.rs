//! Device thread: the PJRT engine is !Send (raw C pointers), so it lives
//! on one dedicated thread and instances call it via channel RPC. On this
//! CPU testbed that is also the honest execution model — all instances
//! share one physical device, like the paper's per-GPU instances share a
//! node (DESIGN.md §2).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::{
    plan_resume, DecodeInput, DecodeOut, Engine, PrefillOut, ResumeOut, ResumePlan, VlmConfig,
};

/// RPC messages to the device thread.
pub enum ExecCall {
    Encode {
        images: Vec<Vec<f32>>,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Prefill {
        tokens: Vec<u32>,
        img_embed: Option<Vec<f32>>,
        reply: Sender<Result<PrefillOut>>,
    },
    /// Resumed (prefill-with-prefix) prefill: only the suffix computes;
    /// the cached prefix is read from the pools via the block table. The
    /// pools travel as `Arc` so one per-batch snapshot serves every
    /// resumed request in the batch without re-copying megabytes per item.
    PrefillResume {
        plan: ResumePlan,
        suffix: Vec<u32>,
        block_table: Vec<u32>,
        k_pool: Arc<Vec<f32>>,
        v_pool: Arc<Vec<f32>>,
        reply: Sender<Result<ResumeOut>>,
    },
    Decode {
        reqs: Vec<DecodeInput>,
        k_pool: Vec<f32>,
        v_pool: Vec<f32>,
        reply: Sender<Result<DecodeOut>>,
    },
    Shutdown,
}

/// Cloneable handle for instances.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<ExecCall>,
    cfg: VlmConfig,
    /// Resumed-prefill suffix buckets, snapshotted at spawn so instances
    /// plan dispatches locally without an RPC round-trip (empty = the
    /// artifacts cannot resume mid-prompt and callers must full-prefill).
    prefill_kv_buckets: Vec<usize>,
}

impl DeviceHandle {
    pub fn cfg(&self) -> &VlmConfig {
        &self.cfg
    }

    /// Can the loaded artifacts ever dispatch a resumed prefill?
    pub fn supports_prefill_resume(&self) -> bool {
        !self.prefill_kv_buckets.is_empty()
    }

    /// Plan a resumed prefill (same bookkeeping as
    /// [`Engine::plan_prefill_resume`], answered from the snapshotted
    /// bucket list — no RPC). `None` always means "run a full prefill".
    pub fn plan_prefill_resume(
        &self,
        prefix_len: usize,
        total_tokens: usize,
        has_image: bool,
    ) -> Option<ResumePlan> {
        plan_resume(&self.prefill_kv_buckets, &self.cfg, prefix_len, total_tokens, has_image)
    }

    pub fn encode(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Encode { images, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn prefill(&self, tokens: Vec<u32>, img_embed: Option<Vec<f32>>) -> Result<PrefillOut> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Prefill { tokens, img_embed, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn prefill_resume(
        &self,
        plan: ResumePlan,
        suffix: Vec<u32>,
        block_table: Vec<u32>,
        k_pool: Arc<Vec<f32>>,
        v_pool: Arc<Vec<f32>>,
    ) -> Result<ResumeOut> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::PrefillResume { plan, suffix, block_table, k_pool, v_pool, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn decode(
        &self,
        reqs: Vec<DecodeInput>,
        k_pool: Vec<f32>,
        v_pool: Vec<f32>,
    ) -> Result<DecodeOut> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Decode { reqs, k_pool, v_pool, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(ExecCall::Shutdown);
    }
}

/// Spawn the device thread; blocks until the engine finished compiling all
/// artifacts (or failed).
pub fn spawn_device(artifacts_dir: &str) -> Result<(DeviceHandle, JoinHandle<()>)> {
    let dir = artifacts_dir.to_string();
    let (tx, rx): (Sender<ExecCall>, Receiver<ExecCall>) = channel();
    let (ready_tx, ready_rx) = channel::<Result<(VlmConfig, Vec<usize>)>>();
    let join = std::thread::Builder::new()
        .name("hydra-device".into())
        .spawn(move || {
            let engine = match Engine::load(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok((*e.cfg(), e.prefill_kv_buckets().to_vec())));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(call) = rx.recv() {
                match call {
                    ExecCall::Encode { images, reply } => {
                        let _ = reply.send(engine.encode(&images));
                    }
                    ExecCall::Prefill { tokens, img_embed, reply } => {
                        let _ = reply.send(engine.prefill(&tokens, img_embed.as_deref()));
                    }
                    ExecCall::PrefillResume {
                        plan,
                        suffix,
                        block_table,
                        k_pool,
                        v_pool,
                        reply,
                    } => {
                        let _ = reply.send(engine.prefill_resume(
                            &plan,
                            &suffix,
                            &block_table,
                            k_pool.as_slice(),
                            v_pool.as_slice(),
                        ));
                    }
                    ExecCall::Decode { reqs, k_pool, v_pool, reply } => {
                        let _ = reply.send(engine.decode(&reqs, &k_pool, &v_pool));
                    }
                    ExecCall::Shutdown => break,
                }
            }
        })
        .expect("spawn device thread");
    let (cfg, prefill_kv_buckets) = ready_rx
        .recv()
        .map_err(|_| anyhow!("device thread died during startup"))??;
    Ok((DeviceHandle { tx, cfg, prefill_kv_buckets }, join))
}
