//! Device thread: the PJRT engine is !Send (raw C pointers), so it lives
//! on one dedicated thread and instances call it via channel RPC. On this
//! CPU testbed that is also the honest execution model — all instances
//! share one physical device, like the paper's per-GPU instances share a
//! node (DESIGN.md §2).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

use crate::runtime::{DecodeInput, DecodeOut, Engine, PrefillOut, VlmConfig};

/// RPC messages to the device thread.
pub enum ExecCall {
    Encode {
        images: Vec<Vec<f32>>,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Prefill {
        tokens: Vec<u32>,
        img_embed: Option<Vec<f32>>,
        reply: Sender<Result<PrefillOut>>,
    },
    Decode {
        reqs: Vec<DecodeInput>,
        k_pool: Vec<f32>,
        v_pool: Vec<f32>,
        reply: Sender<Result<DecodeOut>>,
    },
    Shutdown,
}

/// Cloneable handle for instances.
#[derive(Clone)]
pub struct DeviceHandle {
    tx: Sender<ExecCall>,
    cfg: VlmConfig,
}

impl DeviceHandle {
    pub fn cfg(&self) -> &VlmConfig {
        &self.cfg
    }

    pub fn encode(&self, images: Vec<Vec<f32>>) -> Result<Vec<Vec<f32>>> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Encode { images, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn prefill(&self, tokens: Vec<u32>, img_embed: Option<Vec<f32>>) -> Result<PrefillOut> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Prefill { tokens, img_embed, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn decode(
        &self,
        reqs: Vec<DecodeInput>,
        k_pool: Vec<f32>,
        v_pool: Vec<f32>,
    ) -> Result<DecodeOut> {
        let (tx, rx) = channel();
        self.tx
            .send(ExecCall::Decode { reqs, k_pool, v_pool, reply: tx })
            .map_err(|_| anyhow!("device thread gone"))?;
        rx.recv().map_err(|_| anyhow!("device thread gone"))?
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(ExecCall::Shutdown);
    }
}

/// Spawn the device thread; blocks until the engine finished compiling all
/// artifacts (or failed).
pub fn spawn_device(artifacts_dir: &str) -> Result<(DeviceHandle, JoinHandle<()>)> {
    let dir = artifacts_dir.to_string();
    let (tx, rx): (Sender<ExecCall>, Receiver<ExecCall>) = channel();
    let (ready_tx, ready_rx) = channel::<Result<VlmConfig>>();
    let join = std::thread::Builder::new()
        .name("hydra-device".into())
        .spawn(move || {
            let engine = match Engine::load(&dir) {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(*e.cfg()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(call) = rx.recv() {
                match call {
                    ExecCall::Encode { images, reply } => {
                        let _ = reply.send(engine.encode(&images));
                    }
                    ExecCall::Prefill { tokens, img_embed, reply } => {
                        let _ = reply.send(engine.prefill(&tokens, img_embed.as_deref()));
                    }
                    ExecCall::Decode { reqs, k_pool, v_pool, reply } => {
                        let _ = reply.send(engine.decode(&reqs, &k_pool, &v_pool));
                    }
                    ExecCall::Shutdown => break,
                }
            }
        })
        .expect("spawn device thread");
    let cfg = ready_rx
        .recv()
        .map_err(|_| anyhow!("device thread died during startup"))??;
    Ok((DeviceHandle { tx, cfg }, join))
}
