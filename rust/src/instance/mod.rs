//! Real-execution inference instances and the serving cluster.
//!
//! Each instance is a worker thread owning its scheduler (Algorithm 1 by
//! default), paged KV + image caches with real backing stores, and a mail
//! box for request hand-off: the §4.3 pull-based migration protocol runs
//! over these channels. Compute goes through the shared [`DeviceHandle`]
//! (PJRT executables compiled once from the AOT artifacts). Python is
//! never involved — this is the self-contained serving binary.

pub mod device;

pub use device::{spawn_device, DeviceHandle};

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::{CacheStore, PagedCache};
use crate::config::ControllerConfig;
use crate::controller::{
    ClusterSample, DrainTracker, InstanceSample, ReconfigPolicy, StageLoadEstimator, StageRates,
};
use crate::core::{Lifecycle, Phase, RequestId, RequestSpec, SamplingParams, Stage};
use crate::core::sampling::Sampler;
use crate::migrate::{MigrationKind, Offer, Payload, Pull, Release};
use crate::router::{RoutePolicy, Router};
use crate::runtime::DecodeInput;
use crate::scheduler::{Budgets, Policy, Queues, ReqState, Scheduler, StageMask, TaskWork};
use crate::simulator::ClusterSpec;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::vision::Image;

/// A fully preprocessed request (the paper's §4.1 Request Processor output).
#[derive(Debug, Clone)]
pub struct PreparedRequest {
    pub spec: RequestSpec,
    pub tokens: Vec<u32>,
    /// Normalized pixels, if multimodal.
    pub pixels: Option<Vec<f32>>,
    pub sampling: SamplingParams,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub text: String,
    pub lifecycle: Lifecycle,
}

enum Msg {
    Submit(Box<PreparedRequest>),
    Offer(Box<Offer>),
    Pull(Pull),
    Payload(Box<Payload>),
    Release(Release),
    /// Elastic control plane: drain, then assume this role.
    Reconfigure(StageMask),
    /// The controller gave up on a drain that never emptied.
    CancelDrain,
    /// A peer finished a role flip; update the local peer table.
    PeerMask { idx: usize, mask: StageMask },
    /// A peer started/stopped draining; stop/resume offering it work.
    PeerDrain { idx: usize, draining: bool },
    Shutdown,
}

/// Instance -> controller-thread events.
enum ControlEvent {
    /// Periodic queue-depth observation.
    Sample { idx: usize, sample: InstanceSample },
    /// A drain completed and the role flipped.
    FlipDone { idx: usize, mask: StageMask },
}

/// Live layout state shared between the controller thread, `submit`
/// routing, and the `/status` endpoint.
struct ControlShared {
    masks: Vec<StageMask>,
    draining: Vec<bool>,
    reconfigs: usize,
}

/// Per-request serving data living on whichever instance owns the request.
struct ReqData {
    tokens: Vec<u32>,
    pixels: Option<Vec<f32>>,
    sampler: Sampler,
    generated: Vec<u32>,
    lifecycle: Lifecycle,
    /// Tokens currently materialized in this instance's KV store.
    ctx_len: usize,
    /// Ready-for-work timestamp (queue-time accounting).
    ready_since: f64,
}

struct RealInstance {
    idx: usize,
    mask: StageMask,
    device: DeviceHandle,
    peers: Vec<(Sender<Msg>, StageMask)>,
    results: Sender<ServeResult>,
    epoch: Instant,
    policy: Policy,
    sched: Box<dyn Scheduler>,
    /// Target role while draining (elastic control plane).
    drain_to: Option<StageMask>,
    /// Which peers are mid-drain (kept current by `Msg::PeerDrain`).
    peer_draining: Vec<bool>,
    /// Channel to the controller thread, if elastic mode is on.
    ctrl: Option<Sender<ControlEvent>>,
    last_sample: f64,
    budgets: Budgets,
    queues: Queues,
    kv: PagedCache,
    kv_store: CacheStore,
    img: PagedCache,
    img_store: CacheStore,
    data: HashMap<u64, ReqData>,
    /// Offers waiting for local capacity (pull-based backpressure).
    inbound: Vec<Offer>,
    /// Offers admitted, transfer in flight (we sent Pull, awaiting Payload).
    pending_in: HashMap<u64, Offer>,
    router: Router,
    tokenizer: Tokenizer,
}

impl RealInstance {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    // ---- capacity --------------------------------------------------------

    fn kv_tokens_needed(&self, r: &ReqState) -> usize {
        if !(self.mask.prefill || self.mask.decode) {
            return 0;
        }
        r.spec.prefill_tokens() + if self.mask.decode { r.spec.output_tokens } else { 0 }
    }

    fn img_tokens_needed(&self, r: &ReqState) -> usize {
        let consumes = self.mask.encode
            || (self.mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
        if consumes {
            r.spec.image_tokens()
        } else {
            0
        }
    }

    fn can_admit(&self, r: &ReqState) -> bool {
        let kv_need = crate::util::ceil_div(self.kv_tokens_needed(r), self.kv.block_size().max(1));
        let img_need =
            crate::util::ceil_div(self.img_tokens_needed(r), self.img.block_size().max(1));
        kv_need <= self.kv.free_blocks() && img_need <= self.img.free_blocks()
    }

    fn reserve(&mut self, r: &ReqState) {
        let id = r.spec.id;
        let kv_tokens = self.kv_tokens_needed(r);
        if kv_tokens > 0 && !self.kv.has_request(id) {
            self.kv.allocate(id, kv_tokens).expect("kv capacity checked");
        }
        let img_tokens = self.img_tokens_needed(r);
        if img_tokens > 0 && !self.img.has_request(id) {
            self.img.allocate(id, img_tokens).expect("img capacity checked");
        }
    }

    fn release_caches(&mut self, id: RequestId) {
        if self.kv.has_request(id) {
            self.kv.free(id).unwrap();
        }
        if self.img.has_request(id) {
            self.img.free(id).unwrap();
        }
    }

    // ---- message handling ------------------------------------------------

    fn handle(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => return false,
            Msg::Submit(p) => {
                let now = self.now();
                let mut lc = Lifecycle::new(p.spec.arrival);
                lc.arrival = p.spec.arrival;
                self.data.insert(
                    p.spec.id.0,
                    ReqData {
                        tokens: p.tokens,
                        pixels: p.pixels,
                        sampler: Sampler::new(p.sampling.clone()),
                        generated: Vec::new(),
                        lifecycle: lc,
                        ctx_len: 0,
                        ready_since: now,
                    },
                );
                self.queues.waiting.push_back(ReqState::new(p.spec));
            }
            Msg::Offer(o) => self.inbound.push(*o),
            Msg::Pull(p) => self.serve_pull(p),
            Msg::Payload(pl) => self.receive_payload(*pl),
            Msg::Reconfigure(mask) => self.drain_to = Some(mask),
            Msg::CancelDrain => self.drain_to = None,
            Msg::PeerMask { idx, mask } => {
                if let Some(peer) = self.peers.get_mut(idx) {
                    peer.1 = mask;
                }
            }
            Msg::PeerDrain { idx, draining } => {
                if let Some(f) = self.peer_draining.get_mut(idx) {
                    *f = draining;
                }
            }
            Msg::Release(r) => {
                // step 4: target confirmed receipt; free everything local
                self.release_caches(r.req_id);
                self.data.remove(&r.req_id.0);
                if let Some(pos) =
                    self.queues.running.iter().position(|x| x.spec.id == r.req_id)
                {
                    self.queues.running.remove(pos);
                }
            }
        }
        true
    }

    /// Step 2 (we are the target): admit queued offers when capacity allows.
    fn admit_offers(&mut self) {
        let mut i = 0;
        while i < self.inbound.len() {
            if self.can_admit(&self.inbound[i].req) {
                let offer = self.inbound.remove(i);
                self.reserve(&offer.req);
                let src = offer.src;
                let req_id = offer.req.spec.id;
                self.pending_in.insert(req_id.0, offer);
                let _ = self.peers[src].0.send(Msg::Pull(Pull { req_id, dst: self.idx }));
            } else {
                i += 1;
            }
        }
    }

    /// Step 3 (we are the source): ship the payload.
    fn serve_pull(&mut self, p: Pull) {
        let id = p.req_id;
        let Some(state) = self.queues.running.iter().find(|r| r.spec.id == id) else {
            return;
        };
        let kind = if state.prefill_remaining() > 0 {
            MigrationKind::EncodeToPrefill
        } else {
            MigrationKind::PrefillToDecode
        };
        let payload = match kind {
            MigrationKind::EncodeToPrefill => {
                let slots = self.img.slot_mapping(id).expect("img allocated");
                Payload {
                    req_id: id,
                    kind,
                    img_embed: Some(self.img_store.gather(0, &slots)),
                    kv_planes: None,
                    kv_tokens: 0,
                }
            }
            MigrationKind::PrefillToDecode => {
                let d = self.data.get(&id.0).expect("data present");
                let valid = d.ctx_len;
                let table = self.kv.table(id).expect("kv allocated").clone();
                let slots: Vec<u32> = (0..valid)
                    .map(|pos| table.slot_of(pos, self.kv.block_size()).unwrap())
                    .collect();
                let planes = (0..self.kv_store.num_planes())
                    .map(|pl| self.kv_store.gather(pl, &slots))
                    .collect();
                Payload {
                    req_id: id,
                    kind,
                    img_embed: None,
                    kv_planes: Some(planes),
                    kv_tokens: valid,
                }
            }
        };
        let _ = self.peers[p.dst].0.send(Msg::Payload(Box::new(payload)));
    }

    /// Step 3 receive + step 4 (we are the target).
    fn receive_payload(&mut self, pl: Payload) {
        let id = pl.req_id;
        let Some(offer) = self.pending_in.remove(&id.0) else { return };
        let now = self.now();
        let mut lc = offer.lifecycle;
        let phase = match pl.kind {
            MigrationKind::EncodeToPrefill => Phase::EpMigration,
            MigrationKind::PrefillToDecode => Phase::PdMigration,
        };
        lc.add_phase(phase, offer.offered_at.elapsed().as_secs_f64());

        let mut state = offer.req;
        state.migrating = false;
        let mut ctx_len = 0;
        match pl.kind {
            MigrationKind::EncodeToPrefill => {
                let embed = pl.img_embed.expect("ep payload has embeddings");
                let slots = self.img.slot_mapping(id).expect("img reserved at admit");
                let h = self.img_store.hidden();
                for (i, &slot) in slots.iter().enumerate() {
                    self.img_store.write_token(0, slot, &embed[i * h..(i + 1) * h]);
                }
            }
            MigrationKind::PrefillToDecode => {
                let planes = pl.kv_planes.expect("pd payload has kv");
                ctx_len = pl.kv_tokens;
                let table = self.kv.table(id).expect("kv reserved at admit").clone();
                let slots: Vec<u32> = (0..ctx_len)
                    .map(|pos| table.slot_of(pos, self.kv.block_size()).unwrap())
                    .collect();
                for (p, plane) in planes.into_iter().enumerate() {
                    self.kv_store.scatter(p, &slots, &plane);
                }
            }
        }

        self.data.insert(
            id.0,
            ReqData {
                tokens: offer.tokens,
                pixels: None,
                sampler: Sampler::new(offer.sampling),
                generated: offer.generated,
                lifecycle: lc,
                ctx_len,
                ready_since: now,
            },
        );
        self.queues.running.push(state);
        // step 4: tell the source to release
        let _ = self.peers[offer.src].0.send(Msg::Release(Release { req_id: id }));
    }

    /// Hand a request whose next stage we don't serve to a peer (step 1).
    fn migrate_out(&mut self, id: RequestId) {
        let Some(pos) = self.queues.running.iter().position(|r| r.spec.id == id) else {
            return;
        };
        let state = self.queues.running[pos].clone();
        let next = state.stage();
        let candidates: Vec<usize> = self
            .peers
            .iter()
            .enumerate()
            .filter(|(i, (_, m))| *i != self.idx && m.serves(next))
            .map(|(i, _)| i)
            .collect();
        let Some(dst) = pick_peer(&mut self.router, &candidates, &self.peer_draining) else {
            return; // incomplete cluster: request is stranded
        };
        let kind = if next == Stage::Prefill {
            MigrationKind::EncodeToPrefill
        } else {
            MigrationKind::PrefillToDecode
        };
        self.queues.running[pos].migrating = true;
        let d = self.data.get(&id.0).expect("data present");
        let offer = Offer {
            req: {
                let mut s = state.clone();
                s.migrating = false;
                s
            },
            kind,
            tokens: d.tokens.clone(),
            sampling: d.sampler.params().clone(),
            generated: d.generated.clone(),
            img_embed_floats: state.spec.image_tokens() * self.device.cfg().hidden,
            kv_tokens: d.ctx_len,
            src: self.idx,
            offered_at: Instant::now(),
            lifecycle: d.lifecycle.clone(),
        };
        let _ = self.peers[dst].0.send(Msg::Offer(Box::new(offer)));
    }

    // ---- batch execution ---------------------------------------------------

    /// Build and execute one batch; returns false if there was nothing to do.
    fn step(&mut self) -> Result<bool> {
        self.admit_offers();

        let mut sched = std::mem::replace(&mut self.sched, self.policy.make(self.mask));
        let batch = {
            let kv_free = self.kv.free_blocks();
            let img_free = self.img.free_blocks();
            let kv_bs = self.kv.block_size().max(1);
            let img_bs = self.img.block_size().max(1);
            let mask = self.mask;
            let mut kv_used = 0usize;
            let mut img_used = 0usize;
            let mut admit = |r: &ReqState| {
                let kv_need = crate::util::ceil_div(kv_tokens_needed_mask(mask, r), kv_bs);
                let img_need = crate::util::ceil_div(img_tokens_needed_mask(mask, r), img_bs);
                if kv_used + kv_need <= kv_free && img_used + img_need <= img_free {
                    kv_used += kv_need;
                    img_used += img_need;
                    true
                } else {
                    false
                }
            };
            sched.build_batch(&mut self.queues, &self.budgets, &mut admit)
        };
        self.sched = sched;

        for i in 0..self.queues.running.len() {
            let r = self.queues.running[i].clone();
            self.reserve(&r);
        }

        let started = self.now();
        let mut did_work = false;

        // ---------------- encode (vision stream) ----------------
        let encode_items: Vec<(RequestId, usize)> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::Encode { images } => Some((*id, *images)),
                _ => None,
            })
            .collect();
        if !encode_items.is_empty() {
            let mut pixels = Vec::new();
            for (id, n) in &encode_items {
                let d = self.data.get(&id.0).ok_or_else(|| anyhow!("no data for {id}"))?;
                let px = d.pixels.clone().ok_or_else(|| anyhow!("{id} has no pixels"))?;
                for _ in 0..*n {
                    pixels.push(px.clone()); // one image per request here
                }
            }
            let embeds = self.device.encode(pixels)?;
            let mut k = 0;
            let now = self.now();
            for (id, n) in &encode_items {
                let slots = self.img.slot_mapping(*id).expect("img reserved");
                let h = self.img_store.hidden();
                let embed = &embeds[k];
                for (i, &slot) in slots.iter().enumerate() {
                    self.img_store.write_token(0, slot, &embed[i * h..(i + 1) * h]);
                }
                k += n;
                let d = self.data.get_mut(&id.0).unwrap();
                d.lifecycle.add_phase(Phase::EncodeQueue, (started - d.ready_since).max(0.0));
                d.lifecycle.add_phase(Phase::EncodeExec, now - started);
                d.ready_since = now;
                if let Some(r) = self.queues.find_running(*id) {
                    r.encoded_images += n;
                }
            }
            did_work = true;
        }

        // ---------------- prefill (language stream) ----------------
        let prefill_items: Vec<(RequestId, usize)> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::PrefillChunk { tokens, .. } => Some((*id, *tokens)),
                _ => None,
            })
            .collect();
        for (id, _tokens) in &prefill_items {
            let (spec, has_image) = {
                let r = self
                    .queues
                    .find_running(*id)
                    .ok_or_else(|| anyhow!("prefill req {id} missing"))?;
                (r.spec.clone(), r.spec.has_image())
            };
            let img_embed = if has_image {
                let slots = self.img.slot_mapping(*id)?;
                Some(self.img_store.gather(0, &slots))
            } else {
                None
            };
            let tokens = self.data.get(&id.0).unwrap().tokens.clone();
            let out = self.device.prefill(tokens, img_embed)?;
            let now = self.now();

            // scatter KV into our paged store
            let table = self.kv.table(*id).expect("kv reserved").clone();
            let slots: Vec<u32> = (0..out.valid_len)
                .map(|p| table.slot_of(p, self.kv.block_size()).unwrap())
                .collect();
            let layers = self.device.cfg().layers;
            for (l, (k, v)) in out.k.iter().zip(out.v.iter()).enumerate() {
                self.kv_store.scatter(l, &slots, k);
                self.kv_store.scatter(layers + l, &slots, v);
            }

            // first output token comes from the prefill logits
            let d = self.data.get_mut(&id.0).unwrap();
            let tok = d.sampler.sample(&out.logits);
            d.generated.push(tok);
            d.ctx_len = out.valid_len;
            d.lifecycle.add_phase(Phase::PrefillQueue, (started - d.ready_since).max(0.0));
            d.lifecycle.add_phase(Phase::PrefillExec, now - started);
            d.lifecycle.record_token(now);
            d.ready_since = now;

            // image embeddings consumed
            if self.img.has_request(*id) {
                self.img.free(*id).unwrap();
            }
            let r = self.queues.find_running(*id).unwrap();
            r.prefilled = spec.prefill_tokens();
            r.decoded = 1;
            did_work = true;
        }

        // ---------------- decode (language stream, batched) ----------------
        let decode_ids: Vec<RequestId> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::DecodeToken { .. } => Some(*id),
                _ => None,
            })
            .collect();
        if !decode_ids.is_empty() {
            let mut inputs = Vec::with_capacity(decode_ids.len());
            for id in &decode_ids {
                let d = self.data.get(&id.0).ok_or_else(|| anyhow!("no data for {id}"))?;
                let last = *d.generated.last().expect("decode implies a prior token");
                let table = self.kv.table(*id).expect("kv reserved");
                inputs.push(DecodeInput {
                    token: last,
                    position: d.ctx_len,
                    block_table: table.blocks.clone(),
                    seq_len: d.ctx_len,
                });
            }
            let layers = self.device.cfg().layers;
            let mut k_pool =
                Vec::with_capacity(layers * self.kv_store.plane(0).len());
            let mut v_pool = Vec::with_capacity(k_pool.capacity());
            for l in 0..layers {
                k_pool.extend_from_slice(self.kv_store.plane(l));
            }
            for l in 0..layers {
                v_pool.extend_from_slice(self.kv_store.plane(layers + l));
            }
            let out = self.device.decode(inputs, k_pool, v_pool)?;
            let now = self.now();
            for (i, id) in decode_ids.iter().enumerate() {
                // write the input token's KV at its slot, then advance
                let d = self.data.get_mut(&id.0).unwrap();
                let pos = d.ctx_len;
                let table = self.kv.table(*id).unwrap().clone();
                let slot = table
                    .slot_of(pos, self.kv.block_size())
                    .expect("reserved through output length");
                let h = self.device.cfg().hidden;
                for l in 0..layers {
                    self.kv_store
                        .write_token(l, slot, &out.k_new[i][l * h..(l + 1) * h]);
                    self.kv_store
                        .write_token(layers + l, slot, &out.v_new[i][l * h..(l + 1) * h]);
                }
                let tok = d.sampler.sample(&out.logits[i]);
                d.generated.push(tok);
                d.ctx_len += 1;
                d.lifecycle.add_phase(Phase::DecodeQueue, (started - d.ready_since).max(0.0));
                d.lifecycle.add_phase(Phase::DecodeExec, now - started);
                d.lifecycle.record_token(now);
                d.ready_since = now;
                let r = self.queues.find_running(*id).unwrap();
                r.decoded += 1;
            }
            did_work = true;
        }

        // ---------------- post-batch transitions ----------------
        let ids: Vec<RequestId> = self.queues.running.iter().map(|r| r.spec.id).collect();
        for id in ids {
            let Some(r) = self.queues.find_running(id) else { continue };
            if r.migrating {
                continue;
            }
            if r.finished() {
                self.finish(id);
            } else if !self.mask.serves(r.stage()) {
                self.migrate_out(id);
            }
        }
        Ok(did_work)
    }

    /// Drain-then-flip: once we hold no requests at all, assume the new
    /// role and tell the controller (which updates peers and routing).
    /// Caches are fixed-size pools in real mode, so no resize is needed.
    fn maybe_flip(&mut self) {
        let Some(to) = self.drain_to else { return };
        let empty = self.queues.waiting.is_empty()
            && self.queues.running.is_empty()
            && self.inbound.is_empty()
            && self.pending_in.is_empty();
        if !empty {
            return;
        }
        let from = self.mask;
        self.mask = to;
        self.sched = self.policy.make(to);
        self.drain_to = None;
        crate::util::logging::log(
            crate::util::logging::Level::Info,
            "instance",
            format_args!(
                "instance {} reconfigured {} -> {}",
                self.idx,
                from.label(),
                to.label()
            ),
        );
        if let Some(tx) = &self.ctrl {
            let _ = tx.send(ControlEvent::FlipDone { idx: self.idx, mask: to });
        }
    }

    /// Forward waiting requests this instance can no longer serve. Closes
    /// the submit/flip race: `submit` routes under a snapshot of the
    /// layout, so a request can arrive just after our role changed; the
    /// scheduler would never admit it and it would wait forever. Only the
    /// waiting queue needs this — running requests at an unserved stage
    /// (e.g. an Offer admitted right after a flip) are migrated out by
    /// `step()`'s post-batch transition loop, which runs every iteration.
    fn reroute_unserved(&mut self) {
        if self.ctrl.is_none() {
            return; // static layout: masks never change, nothing can strand
        }
        let mut i = 0;
        while i < self.queues.waiting.len() {
            let stage = self.queues.waiting[i].stage();
            if self.mask.serves(stage) {
                i += 1;
                continue;
            }
            let candidates: Vec<usize> = self
                .peers
                .iter()
                .enumerate()
                .filter(|(j, (_, m))| *j != self.idx && m.serves(stage))
                .map(|(j, _)| j)
                .collect();
            if candidates.is_empty() {
                i += 1; // incomplete cluster: nowhere better to send it
                continue;
            }
            let Some(dst) = pick_peer(&mut self.router, &candidates, &self.peer_draining)
            else {
                i += 1;
                continue;
            };
            let r = self.queues.waiting.remove(i).unwrap();
            let Some(d) = self.data.remove(&r.spec.id.0) else { continue };
            // a waiting request has made no progress: re-submit it whole
            let prepared = PreparedRequest {
                spec: r.spec,
                tokens: d.tokens,
                pixels: d.pixels,
                sampling: d.sampler.params().clone(),
            };
            let _ = self.peers[dst].0.send(Msg::Submit(Box::new(prepared)));
        }
    }

    /// Periodic queue-depth sample for the controller's estimator.
    fn maybe_sample(&mut self) {
        if self.ctrl.is_none() {
            return;
        }
        let now = self.now();
        if now - self.last_sample < 0.05 {
            return;
        }
        self.last_sample = now;
        let mut s = InstanceSample::idle(self.mask, self.drain_to.is_some());
        // migrating requests are counted at the pulling side
        for r in self
            .queues
            .waiting
            .iter()
            .chain(self.queues.running.iter().filter(|r| !r.migrating))
        {
            s.add_req(r);
        }
        for o in &self.inbound {
            s.add_req(&o.req);
        }
        for o in self.pending_in.values() {
            s.add_req(&o.req);
        }
        if let Some(tx) = &self.ctrl {
            let _ = tx.send(ControlEvent::Sample { idx: self.idx, sample: s });
        }
    }

    fn finish(&mut self, id: RequestId) {
        let Some(pos) = self.queues.running.iter().position(|r| r.spec.id == id) else {
            return;
        };
        self.queues.running.remove(pos);
        self.release_caches(id);
        if let Some(mut d) = self.data.remove(&id.0) {
            d.lifecycle.finished_at = Some(self.now());
            let text = self.tokenizer.decode(&d.generated);
            let _ = self.results.send(ServeResult {
                id,
                tokens: d.generated,
                text,
                lifecycle: d.lifecycle,
            });
        }
    }

    fn run(mut self, rx: Receiver<Msg>) {
        loop {
            // drain everything pending
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !self.handle(msg) {
                            return;
                        }
                    }
                    Err(_) => break,
                }
            }
            self.maybe_flip();
            self.reroute_unserved();
            self.maybe_sample();
            let worked = match self.step() {
                Ok(w) => w,
                Err(e) => {
                    crate::util::logging::log(
                        crate::util::logging::Level::Error,
                        "instance",
                        format_args!("instance {} batch failed: {e:#}", self.idx),
                    );
                    false
                }
            };
            if !worked {
                // idle: block for the next message (with a timeout so queued
                // offers get re-checked for capacity)
                match rx.recv_timeout(Duration::from_millis(5)) {
                    Ok(msg) => {
                        if !self.handle(msg) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            }
        }
    }
}

/// Round-robin over `candidates`, skipping mid-drain peers; falls back to
/// them when no one else is eligible, so work is never dropped just
/// because a reconfiguration is in flight. Returns the chosen instance
/// index (the real-mode analogue of the simulator's `route_among`).
fn pick_peer(router: &mut Router, candidates: &[usize], draining: &[bool]) -> Option<usize> {
    if candidates.is_empty() {
        return None;
    }
    let gated: Vec<f64> = candidates
        .iter()
        .map(|&j| {
            if draining.get(j).copied().unwrap_or(false) {
                f64::INFINITY
            } else {
                0.0
            }
        })
        .collect();
    if let Some(p) = router.pick(&gated) {
        return Some(candidates[p]);
    }
    let raw = vec![0.0; candidates.len()];
    router.pick(&raw).map(|p| candidates[p])
}

fn kv_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    if !(mask.prefill || mask.decode) {
        return 0;
    }
    r.spec.prefill_tokens() + if mask.decode { r.spec.output_tokens } else { 0 }
}

fn img_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    let consumes = mask.encode || (mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
    if consumes {
        r.spec.image_tokens()
    } else {
        0
    }
}

// ---------------------------------------------------------------------------
// Cluster
// ---------------------------------------------------------------------------

/// A running disaggregated serving cluster (real execution).
pub struct RealCluster {
    senders: Vec<Sender<Msg>>,
    masks: Vec<StageMask>,
    results_rx: Option<Receiver<ServeResult>>,
    device: DeviceHandle,
    joins: Vec<JoinHandle<()>>,
    device_join: Option<JoinHandle<()>>,
    router: Router,
    tokenizer: Tokenizer,
    epoch: Instant,
    next_id: u64,
    /// Elastic control plane (None = static layout).
    control: Option<Arc<Mutex<ControlShared>>>,
    ctrl_stop: Arc<AtomicBool>,
    ctrl_join: Option<JoinHandle<()>>,
}

impl RealCluster {
    /// Boot the device thread + one worker thread per instance with a
    /// static layout (the elastic controller off).
    pub fn start(artifacts_dir: &str, cluster: &ClusterSpec, policy: Policy) -> Result<RealCluster> {
        RealCluster::start_with_controller(artifacts_dir, cluster, policy, None)
    }

    /// Boot the cluster, optionally with the elastic control plane: a
    /// controller thread consumes per-instance queue samples, runs the
    /// estimator + reconfiguration policy, and drives drain-then-flip
    /// role changes over the instance mailboxes.
    pub fn start_with_controller(
        artifacts_dir: &str,
        cluster: &ClusterSpec,
        policy: Policy,
        controller: Option<ControllerConfig>,
    ) -> Result<RealCluster> {
        let (device, device_join) = spawn_device(artifacts_dir)?;
        let cfg = *device.cfg();
        let masks = cluster.instance_masks();
        let epoch = Instant::now();
        let (results_tx, results_rx) = channel();

        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in &masks {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(rx);
        }

        let ctrl_stop = Arc::new(AtomicBool::new(false));
        let (ctrl_tx, ctrl_rx, control) = match &controller {
            Some(_) => {
                let (tx, rx) = channel::<ControlEvent>();
                let shared = Arc::new(Mutex::new(ControlShared {
                    masks: masks.clone(),
                    draining: vec![false; masks.len()],
                    reconfigs: 0,
                }));
                (Some(tx), Some(rx), Some(shared))
            }
            None => (None, None, None),
        };

        let budgets = Budgets {
            token_budget: 1024, // prompts always fit one bucket: never chunked
            image_budget: 4,    // largest encode artifact bucket
            max_decode_batch: 8, // largest decode artifact bucket
        };

        let mut joins = Vec::new();
        for (idx, rx) in receivers.into_iter().enumerate() {
            let mask = masks[idx];
            let peers: Vec<(Sender<Msg>, StageMask)> = senders
                .iter()
                .cloned()
                .zip(masks.iter().copied())
                .collect();
            let planes = 2 * cfg.layers;
            let inst = RealInstance {
                idx,
                mask,
                device: device.clone(),
                peers,
                results: results_tx.clone(),
                epoch,
                policy,
                sched: policy.make(mask),
                drain_to: None,
                peer_draining: vec![false; masks.len()],
                ctrl: ctrl_tx.clone(),
                last_sample: 0.0,
                budgets,
                queues: Queues::default(),
                kv: PagedCache::new(cfg.pool_blocks, cfg.block_size, cfg.max_blocks_per_seq),
                kv_store: CacheStore::new(planes, cfg.pool_blocks, cfg.block_size, cfg.hidden),
                img: PagedCache::new(64, cfg.img_tokens, 4),
                img_store: CacheStore::new(1, 64, cfg.img_tokens, cfg.hidden),
                data: HashMap::new(),
                inbound: Vec::new(),
                pending_in: HashMap::new(),
                router: Router::new(RoutePolicy::RoundRobin, idx as u64),
                tokenizer: Tokenizer::new(),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("hydra-inst-{idx}"))
                    .spawn(move || inst.run(rx))
                    .expect("spawn instance"),
            );
        }

        drop(ctrl_tx); // controller rx must disconnect when instances exit

        let ctrl_join = match (controller, ctrl_rx, control.clone()) {
            (Some(cc), Some(rx), Some(shared)) => Some(spawn_controller_thread(
                cc,
                rx,
                shared,
                senders.clone(),
                epoch,
                Arc::clone(&ctrl_stop),
            )),
            _ => None,
        };

        Ok(RealCluster {
            senders,
            masks,
            results_rx: Some(results_rx),
            device,
            joins,
            device_join: Some(device_join),
            router: Router::new(RoutePolicy::RoundRobin, 7),
            tokenizer: Tokenizer::new(),
            epoch,
            next_id: 0,
            control,
            ctrl_stop,
            ctrl_join,
        })
    }

    pub fn cfg(&self) -> &crate::runtime::VlmConfig {
        self.device.cfg()
    }

    /// The id the next `submit` will assign (the API server registers its
    /// result waiter before submitting to avoid a race).
    pub fn peek_next_id(&self) -> u64 {
        self.next_id
    }

    /// Preprocess (tokenize + image) and dispatch a request. Returns its id.
    pub fn submit(
        &mut self,
        prompt: &str,
        image: Option<&Image>,
        sampling: SamplingParams,
    ) -> Result<RequestId> {
        let cfg = *self.device.cfg();
        let tokens = self.tokenizer.apply_chat_template(prompt, image.is_some());
        let max_txt = if image.is_some() {
            // largest mm bucket minus image tokens
            80 - cfg.img_tokens
        } else {
            64
        };
        if tokens.len() > max_txt {
            anyhow::bail!("prompt too long: {} tokens > {max_txt}", tokens.len());
        }
        let pixels = image.map(|img| img.preprocess(cfg.img_size));
        let prefill = tokens.len() + if image.is_some() { cfg.img_tokens } else { 0 };
        let max_out = cfg.max_context().saturating_sub(prefill + 1);
        let mut sampling = sampling;
        sampling.max_tokens = sampling.max_tokens.clamp(1, max_out);

        let id = RequestId(self.next_id);
        self.next_id += 1;
        let spec = RequestSpec {
            id,
            arrival: self.epoch.elapsed().as_secs_f64(),
            num_images: usize::from(image.is_some()),
            tokens_per_image: cfg.img_tokens,
            prompt_tokens: tokens.len(),
            output_tokens: sampling.max_tokens,
        };
        let first = spec.first_stage();
        // live layout: under the elastic controller, masks change and
        // draining instances must not receive new work
        let (masks, draining) = match &self.control {
            Some(c) => {
                let s = c.lock().unwrap();
                (s.masks.clone(), s.draining.clone())
            }
            None => (self.masks.clone(), vec![false; self.masks.len()]),
        };
        let candidates: Vec<usize> =
            (0..masks.len()).filter(|&i| masks[i].serves(first)).collect();
        let target = pick_peer(&mut self.router, &candidates, &draining)
            .ok_or_else(|| anyhow!("no instance serves {first:?}"))?;
        self.senders[target]
            .send(Msg::Submit(Box::new(PreparedRequest { spec, tokens, pixels, sampling })))
            .map_err(|_| anyhow!("instance {target} is down"))?;
        Ok(id)
    }

    /// Collect `n` results (blocking, with an overall timeout). Panics if
    /// the results receiver was taken (API-server mode).
    pub fn collect(&self, n: usize, timeout: Duration) -> Vec<ServeResult> {
        let rx = self.results_rx.as_ref().expect("results receiver taken");
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => out.push(r),
                Err(_) => break,
            }
        }
        out
    }

    /// Move the results receiver out (for a dispatcher thread, e.g. the
    /// HTTP API). After this, `collect` must not be used.
    pub fn take_results(&mut self) -> Option<Receiver<ServeResult>> {
        self.results_rx.take()
    }

    /// Live layout + controller state (the `/status` endpoint's body).
    pub fn status(&self) -> Json {
        let (masks, draining, reconfigs, elastic) = match &self.control {
            Some(c) => {
                let s = c.lock().unwrap();
                (s.masks.clone(), s.draining.clone(), s.reconfigs, true)
            }
            None => (self.masks.clone(), vec![false; self.masks.len()], 0, false),
        };
        let instances: Vec<Json> = masks
            .iter()
            .zip(&draining)
            .enumerate()
            .map(|(i, (m, d))| {
                Json::obj(vec![
                    ("idx", Json::num(i as f64)),
                    ("stages", Json::str(m.label())),
                    ("draining", Json::Bool(*d)),
                ])
            })
            .collect();
        let label = masks.iter().map(|m| m.label()).collect::<Vec<_>>().join("+");
        Json::obj(vec![
            ("cluster", Json::str(label)),
            ("elastic", Json::Bool(elastic)),
            ("reconfigs", Json::num(reconfigs as f64)),
            ("instances", Json::arr(instances)),
        ])
    }

    /// Graceful shutdown: stop instances, the controller, then the device.
    pub fn shutdown(mut self) {
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.ctrl_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.ctrl_join.take() {
            let _ = j.join();
        }
        self.device.shutdown();
        if let Some(j) = self.device_join.take() {
            let _ = j.join();
        }
    }
}

/// The elastic controller thread: folds instance samples into the
/// estimator, runs the flip policy once per tick, and finalizes flips
/// (peer-table updates + shared layout state) when instances report done.
fn spawn_controller_thread(
    cc: ControllerConfig,
    rx: Receiver<ControlEvent>,
    shared: Arc<Mutex<ControlShared>>,
    senders: Vec<Sender<Msg>>,
    epoch: Instant,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("hydra-controller".into())
        .spawn(move || {
            let n = senders.len();
            let mut est =
                StageLoadEstimator::new(cc.clone(), StageRates::default_real(), None);
            let mut pol = ReconfigPolicy::new(cc.clone());
            let mut tracker = DrainTracker::new(n);
            let mut latest: Vec<Option<InstanceSample>> = vec![None; n];
            let mut last_tick = 0.0f64;
            let poll = Duration::from_millis(((cc.tick * 500.0) as u64).max(1));
            let broadcast_drain = |senders: &[Sender<Msg>], idx: usize, draining: bool| {
                for tx in senders {
                    let _ = tx.send(Msg::PeerDrain { idx, draining });
                }
            };
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match rx.recv_timeout(poll) {
                    Ok(ControlEvent::Sample { idx, sample }) => {
                        if idx < n {
                            latest[idx] = Some(sample);
                        }
                    }
                    Ok(ControlEvent::FlipDone { idx, mask }) => {
                        let now = epoch.elapsed().as_secs_f64();
                        let from = {
                            let mut s = shared.lock().unwrap();
                            let from = s.masks[idx];
                            s.masks[idx] = mask;
                            s.draining[idx] = false;
                            s.reconfigs += 1;
                            from
                        };
                        // may race with a just-sent CancelDrain; the flip won
                        if tracker.is_draining(idx) {
                            tracker.complete(now, idx, from);
                        }
                        for tx in &senders {
                            let _ = tx.send(Msg::PeerMask { idx, mask });
                        }
                        broadcast_drain(&senders, idx, false);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                let now = epoch.elapsed().as_secs_f64();
                if now - last_tick < cc.tick {
                    continue;
                }
                last_tick = now;
                // give up on drains that never empty (sustained inflow):
                // the instance keeps its current role and rejoins routing
                for i in 0..n {
                    if tracker.is_draining(i) && tracker.expired(now, i, cc.drain_timeout) {
                        tracker.cancel(i);
                        shared.lock().unwrap().draining[i] = false;
                        let _ = senders[i].send(Msg::CancelDrain);
                        broadcast_drain(&senders, i, false);
                    }
                }
                let (masks, draining) = {
                    let s = shared.lock().unwrap();
                    (s.masks.clone(), s.draining.clone())
                };
                let insts: Vec<InstanceSample> = (0..n)
                    .map(|i| {
                        latest[i]
                            .clone()
                            .unwrap_or_else(|| InstanceSample::idle(masks[i], draining[i]))
                    })
                    .collect();
                est.observe(ClusterSample {
                    t: now,
                    instances: insts,
                    ttft_p90: None,
                    tpot_p90: None,
                });
                let Some(load) = est.snapshot() else { continue };
                if let Some(d) = pol.decide(now, &load, &masks, &draining) {
                    if tracker.begin(now, d.instance, d.to) {
                        shared.lock().unwrap().draining[d.instance] = true;
                        let _ = senders[d.instance].send(Msg::Reconfigure(d.to));
                        broadcast_drain(&senders, d.instance, true);
                    }
                }
            }
        })
        .expect("spawn controller")
}
