//! Real-execution inference instances and the serving cluster.
//!
//! Each instance is a worker thread owning its scheduler (Algorithm 1 by
//! default), paged KV + image caches with real backing stores, and a mail
//! box for request hand-off: the §4.3 pull-based migration protocol runs
//! over these channels. Compute goes through the shared [`DeviceHandle`]
//! (PJRT executables compiled once from the AOT artifacts). Python is
//! never involved — this is the self-contained serving binary.
//!
//! Cached KV prefixes save real compute here, not just transfer bytes:
//! when the artifacts ship `prefill_kv_s*` suffix buckets, `submit` pins
//! the longest cached prompt prefix (`PagedCache::acquire_prefix`) and
//! pre-advances the request's `prefilled` progress, so the scheduler
//! charges only the suffix against its token budget and the prefill step
//! dispatches a suffix-sized resumed prefill
//! (`DeviceHandle::prefill_resume`) over the pinned pool rows; a
//! migrated-in request applies the KV its cache already held the same
//! way. Without those buckets nothing is advanced and behaviour is
//! bit-identical to full prefill.

pub mod device;

pub use device::{spawn_device, DeviceHandle};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cache::{content, BlockHash, CacheStore, ContentDirectory, PagedCache, COST_IMAGE};
use crate::config::{ControllerConfig, SupervisorConfig};
use crate::faults::RetryPolicy;
use crate::controller::{
    ClusterSample, DrainTracker, InstanceSample, ReconfigPolicy, StageLoadEstimator, StageRates,
};
use crate::core::{Lifecycle, Phase, RequestId, RequestSpec, SamplingParams, Stage};
use crate::core::sampling::Sampler;
use crate::migrate::{MigrationKind, Offer, Payload, Pull, Release};
use crate::obs::registry::{Counter, Gauge, Registry, StreamHist};
use crate::obs::trace::{chrome_trace_json, mask_bits, Span, SpanKind, Tracer};
use crate::router::{RoutePolicy, Router};
use crate::runtime::DecodeInput;
use crate::scheduler::{Budgets, Policy, Queues, ReqState, Scheduler, StageMask, TaskWork};
use crate::simulator::ClusterSpec;
use crate::tokenizer::Tokenizer;
use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;
use crate::vision::Image;

/// A fully preprocessed request (the paper's §4.1 Request Processor output).
#[derive(Debug, Clone)]
pub struct PreparedRequest {
    pub spec: RequestSpec,
    pub tokens: Vec<u32>,
    /// Normalized pixels, if multimodal.
    pub pixels: Option<Vec<f32>>,
    pub sampling: SamplingParams,
    /// Dispatch epoch: 0 on first dispatch, bumped by the cluster each
    /// time the request is re-dispatched after its target was marked
    /// dead. Finish accounting stays exactly-once regardless of epochs:
    /// the cluster accepts the first result per request id and drops
    /// late duplicates from superseded dispatches.
    pub epoch: u32,
}

/// A finished request.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: RequestId,
    pub tokens: Vec<u32>,
    pub text: String,
    pub lifecycle: Lifecycle,
    /// `None` = clean finish. `Some` = the request was dead-lettered (a
    /// repeatedly failing batch, or a dead instance with no live
    /// replacement); `tokens`/`text` carry whatever was generated before
    /// the failure. Structured error responses replace silent drops.
    pub error: Option<String>,
}

/// Typed failure from [`RealCluster::collect`] — previously a timeout
/// panicked (`expect`) and partial progress was silently discarded.
#[derive(Debug)]
pub enum CollectError {
    /// The deadline passed (or every producer hung up) before all
    /// `expected` results arrived; the results that did arrive are
    /// returned in `partial` rather than dropped.
    Timeout { partial: Vec<ServeResult>, expected: usize },
    /// [`RealCluster::take_results`] moved the receiver out (API-server
    /// mode); `collect` has nothing to read from.
    ReceiverTaken,
}

impl std::fmt::Display for CollectError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CollectError::Timeout { partial, expected } => write!(
                f,
                "collect timed out with {}/{} results",
                partial.len(),
                expected
            ),
            CollectError::ReceiverTaken => {
                write!(f, "results receiver was taken (API-server mode)")
            }
        }
    }
}

impl std::error::Error for CollectError {}

/// Receive up to `n` results within `timeout`; `Ok` when all arrived,
/// `Err(Timeout {{ partial, .. }})` otherwise (disconnection of every
/// sender counts as a timeout — whatever arrived is still returned).
/// The primitive under [`RealCluster::collect`], split out so the
/// timeout contract has a cluster-free regression test.
pub fn collect_results(
    rx: &Receiver<ServeResult>,
    n: usize,
    timeout: Duration,
) -> std::result::Result<Vec<ServeResult>, CollectError> {
    let deadline = Instant::now() + timeout;
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let now = Instant::now();
        if now >= deadline {
            return Err(CollectError::Timeout { partial: out, expected: n });
        }
        match rx.recv_timeout(deadline - now) {
            Ok(r) => out.push(r),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                return Err(CollectError::Timeout { partial: out, expected: n })
            }
        }
    }
    Ok(out)
}

/// Which cache plane a directory/gossip message refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Plane {
    Kv,
    Img,
}

enum Msg {
    Submit(Box<PreparedRequest>),
    Offer(Box<Offer>),
    Pull(Pull),
    Payload(Box<Payload>),
    Release(Release),
    /// Content-directory gossip: a peer committed these hashes to its
    /// cache index. Each instance folds the update into its local
    /// directory replica (used for peer-pull decisions).
    PublishContent { idx: usize, plane: Plane, hashes: Vec<BlockHash> },
    /// Content-directory gossip: pool pressure evicted these hashes from
    /// a peer's cache index (or a role flip dropped its cache).
    RetractContent { idx: usize, plane: Plane, hashes: Vec<BlockHash> },
    /// Peer-pull request: `dst` wants the image-embedding blocks behind
    /// `hashes` (fetch-over-recompute — re-encoding is far costlier than
    /// copying the cached embedding).
    FetchContent { req_id: RequestId, dst: usize, hashes: Vec<BlockHash> },
    /// Peer-pull reply: the gathered embedding rows (`None` = the content
    /// was already evicted here — a stale advertisement).
    CacheData { req_id: RequestId, data: Option<Vec<f32>> },
    /// Elastic control plane: drain, then assume this role.
    Reconfigure(StageMask),
    /// The controller gave up on a drain that never emptied.
    CancelDrain,
    /// A peer finished a role flip; update the local peer table.
    PeerMask { idx: usize, mask: StageMask },
    /// A peer started/stopped draining; stop/resume offering it work.
    PeerDrain { idx: usize, draining: bool },
    Shutdown,
}

/// Instance -> controller-thread events.
enum ControlEvent {
    /// Periodic queue-depth observation.
    Sample { idx: usize, sample: InstanceSample },
    /// A drain completed and the role flipped.
    FlipDone { idx: usize, mask: StageMask },
    /// A request finished; its lifecycle feeds the controller's windowed
    /// TTFT/TPOT tails (previously real mode only reported queue depths —
    /// finished-request latencies went to the results channel and never
    /// reached the estimator).
    Finished(Box<Lifecycle>),
}

/// Live layout state shared between the controller thread, `submit`
/// routing, and the `/status` endpoint.
struct ControlShared {
    masks: Vec<StageMask>,
    draining: Vec<bool>,
    reconfigs: usize,
}

/// Cluster-wide content-directory view. Instances publish/retract into it
/// as their cache indexes change (and gossip the same updates to every
/// peer's local replica); the cluster router reads it to route repeated
/// content back to its holders — replacing the old ad-hoc
/// "content key -> last instance" affinity memory with the actual
/// block-level truth.
struct SharedDirectory {
    kv: ContentDirectory,
    img: ContentDirectory,
    /// Image embeddings served by peer-pull instead of re-encoding.
    peer_pulls: usize,
    /// Peer-pulls that missed (advertisement went stale) or timed out.
    stale_pulls: usize,
}

/// Per-request serving data living on whichever instance owns the request.
struct ReqData {
    tokens: Vec<u32>,
    pixels: Option<Vec<f32>>,
    sampler: Sampler,
    generated: Vec<u32>,
    lifecycle: Lifecycle,
    /// Tokens currently materialized in this instance's KV store.
    ctx_len: usize,
    /// Ready-for-work timestamp (queue-time accounting).
    ready_since: f64,
    /// Chained content hashes of the prompt-region KV blocks (real token
    /// ids + image identity) — drives prefix sharing and delta migration.
    kv_hashes: Vec<u64>,
    /// Content hashes of the image-embedding blocks (pixel hash).
    img_hashes: Vec<u64>,
}

/// Per-instance observability handles, created once at boot so the hot
/// serving loop touches atomics and its own (uncontended) flight-recorder
/// ring — never the registry's name map. TTFT/TPOT histograms and the
/// admission/finish counters are shared cluster-wide (same registry name
/// resolves to the same instrument); queue-depth and occupancy gauges are
/// per-instance labeled series.
struct InstanceObs {
    /// Backlog by stage: `[encode, prefill, decode]` waiting+running items.
    queue_depth: [Arc<Gauge>; 3],
    /// Items in the batch the last `step` dispatched.
    batch_occupancy: Arc<Gauge>,
    ttft: Arc<Mutex<StreamHist>>,
    tpot: Arc<Mutex<StreamHist>>,
    finished: Arc<Counter>,
    migrations: Arc<Counter>,
    /// Batch steps that returned an error (each failure also logs; after
    /// `RetryPolicy::max_attempts` consecutive ones the batch's requests
    /// are dead-lettered).
    batch_failures: Arc<Counter>,
    /// Requests answered with a structured error response instead of a
    /// clean finish (shared instrument with the cluster-side dead-letter
    /// path — same registry name).
    dead_letters: Arc<Counter>,
    /// This instance's flight recorder (the cluster merges snapshots for
    /// `/trace`; only the owning thread writes, so the lock is free).
    tracer: Arc<Mutex<Tracer>>,
}

impl InstanceObs {
    fn new(reg: &Registry, idx: usize, tracer: Arc<Mutex<Tracer>>) -> InstanceObs {
        let depth = |stage: &str| {
            reg.gauge(&format!("hydra_queue_depth{{instance=\"{idx}\",stage=\"{stage}\"}}"))
        };
        InstanceObs {
            queue_depth: [depth("encode"), depth("prefill"), depth("decode")],
            batch_occupancy: reg.gauge(&format!("hydra_batch_occupancy{{instance=\"{idx}\"}}")),
            ttft: reg.histogram("hydra_ttft_seconds"),
            tpot: reg.histogram("hydra_tpot_seconds"),
            finished: reg.counter("hydra_requests_finished_total"),
            migrations: reg.counter("hydra_migrations_total"),
            batch_failures: reg.counter("hydra_batch_failures_total"),
            dead_letters: reg.counter("hydra_dead_letters_total"),
            tracer,
        }
    }
}

struct RealInstance {
    idx: usize,
    mask: StageMask,
    device: DeviceHandle,
    peers: Vec<(Sender<Msg>, StageMask)>,
    results: Sender<ServeResult>,
    epoch: Instant,
    policy: Policy,
    sched: Box<dyn Scheduler>,
    /// Target role while draining (elastic control plane).
    drain_to: Option<StageMask>,
    /// Which peers are mid-drain (kept current by `Msg::PeerDrain`).
    peer_draining: Vec<bool>,
    /// Channel to the controller thread, if elastic mode is on.
    ctrl: Option<Sender<ControlEvent>>,
    last_sample: f64,
    budgets: Budgets,
    queues: Queues,
    kv: PagedCache,
    kv_store: CacheStore,
    img: PagedCache,
    img_store: CacheStore,
    data: FxHashMap<u64, ReqData>,
    /// Offers waiting for local capacity (pull-based backpressure).
    inbound: Vec<Offer>,
    /// Offers admitted, transfer in flight (we sent Pull, awaiting
    /// Payload), plus the KV tokens our own cache already held at admit
    /// time — the resumed-prefill credit applied when the payload lands.
    pending_in: FxHashMap<u64, (Offer, usize)>,
    /// Local content-directory replica: own commits applied directly,
    /// peers' via `Msg::{PublishContent, RetractContent}` gossip. Drives
    /// the peer-pull decision without touching the shared lock.
    dir_kv: ContentDirectory,
    dir_img: ContentDirectory,
    /// The router's shared view (kept in sync on every publish/retract).
    shared_dir: Arc<Mutex<SharedDirectory>>,
    /// Requests parked while an image-embedding peer-pull is in flight:
    /// id -> (request, give-up deadline). On `CacheData` they resume with
    /// the embedding installed; past the deadline they fall back to
    /// encoding locally.
    fetch_parked: FxHashMap<u64, (ReqState, f64)>,
    router: Router,
    tokenizer: Tokenizer,
    /// Reusable slot-id buffer for `PagedCache::slot_mapping_into` — the
    /// per-batch gather/scatter paths must not allocate a fresh `Vec` per
    /// request.
    scratch_slots: Vec<u32>,
    /// Milliseconds since cluster epoch, stamped at the top of every
    /// serving-loop pass; the supervisor thread reads it to decide
    /// liveness.
    heartbeat: Arc<AtomicU64>,
    /// Backoff schedule for consecutive batch failures.
    retry: RetryPolicy,
    /// Consecutive `step()` errors; reset on any success. At
    /// `retry.max_attempts` the failing batch's requests are
    /// dead-lettered instead of silently spinning forever.
    failed_steps: usize,
    /// Metrics handles + flight recorder (`obs`).
    obs: InstanceObs,
}

impl RealInstance {
    fn now(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64()
    }

    // ---- capacity --------------------------------------------------------

    fn kv_tokens_needed(&self, r: &ReqState) -> usize {
        if !(self.mask.prefill || self.mask.decode) {
            return 0;
        }
        r.spec.prefill_tokens() + if self.mask.decode { r.spec.output_tokens } else { 0 }
    }

    fn img_tokens_needed(&self, r: &ReqState) -> usize {
        let consumes = self.mask.encode
            || (self.mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
        if consumes {
            r.spec.image_tokens()
        } else {
            0
        }
    }

    /// Admission check: blocks a request already pinned (cached prefix)
    /// cost nothing, and evictable cached blocks count as reclaimable
    /// capacity — only genuine pressure backpressures.
    fn can_admit(&self, r: &ReqState) -> bool {
        let kv_need = crate::util::ceil_div(self.kv_tokens_needed(r), self.kv.block_size().max(1))
            .saturating_sub(self.kv.held_blocks(r.spec.id));
        let img_need =
            crate::util::ceil_div(self.img_tokens_needed(r), self.img.block_size().max(1))
                .saturating_sub(self.img.held_blocks(r.spec.id));
        kv_need <= self.kv.available_blocks() && img_need <= self.img.available_blocks()
    }

    fn reserve(&mut self, r: &ReqState) {
        let id = r.spec.id;
        let kv_tokens = self.kv_tokens_needed(r);
        if kv_tokens > 0 {
            if !self.kv.has_request(id) {
                // pin any committed prompt-prefix blocks (identical
                // content: prefill rewrites them with the same values).
                // Hashes were memoized at submit — borrow, never re-derive.
                let hashes: &[BlockHash] =
                    self.data.get(&id.0).map_or(&[], |d| d.kv_hashes.as_slice());
                let _ = self.kv.acquire_prefix(
                    id,
                    hashes,
                    r.spec.prefill_tokens().saturating_sub(1),
                );
            }
            self.kv.grow(id, kv_tokens).expect("kv capacity checked");
        }
        let img_tokens = self.img_tokens_needed(r);
        if img_tokens > 0 {
            if !self.img.has_request(id) {
                let hashes: &[BlockHash] =
                    self.data.get(&id.0).map_or(&[], |d| d.img_hashes.as_slice());
                let _ = self.img.acquire_prefix(id, hashes, img_tokens);
            }
            self.img.grow(id, img_tokens).expect("img capacity checked");
        }
    }

    /// Reserve for an inbound migration offer, using the offer's content
    /// hashes; returns (KV tokens, full-image hit) already held locally —
    /// the delta-pull credit (paper §4.3 step 2 + §4.5 reuse).
    fn reserve_offer(&mut self, o: &Offer) -> (usize, bool) {
        let r = &o.req;
        let id = r.spec.id;
        let mut kv_have = 0usize;
        let mut img_have = false;
        let kv_tokens = self.kv_tokens_needed(r);
        if kv_tokens > 0 {
            if !self.kv.has_request(id) {
                kv_have = self
                    .kv
                    .acquire_prefix(
                        id,
                        &o.kv_block_hashes,
                        r.spec.prefill_tokens().saturating_sub(1),
                    )
                    .unwrap_or(0);
            }
            self.kv.grow(id, kv_tokens).expect("kv capacity checked");
        }
        let img_tokens = self.img_tokens_needed(r);
        if img_tokens > 0 {
            if !self.img.has_request(id) {
                let cached = self
                    .img
                    .acquire_prefix(id, &o.img_block_hashes, img_tokens)
                    .unwrap_or(0);
                img_have = cached >= img_tokens;
            }
            self.img.grow(id, img_tokens).expect("img capacity checked");
        }
        (kv_have, img_have)
    }

    fn release_caches(&mut self, id: RequestId) {
        release_cache_pair(&mut self.kv, &mut self.img, id);
    }

    /// Snapshot the LM KV pools in the `[layers, pool_blocks, block_size,
    /// hidden]` layout `Engine::{decode, prefill_resume}` expect (K planes
    /// are backing-store planes `0..L`, V planes `L..2L`).
    fn lm_pools(&self) -> (Vec<f32>, Vec<f32>) {
        let layers = self.device.cfg().layers;
        let mut k_pool = Vec::with_capacity(layers * self.kv_store.plane(0).len());
        let mut v_pool = Vec::with_capacity(k_pool.capacity());
        for l in 0..layers {
            k_pool.extend_from_slice(self.kv_store.plane(l));
        }
        for l in 0..layers {
            v_pool.extend_from_slice(self.kv_store.plane(layers + l));
        }
        (k_pool, v_pool)
    }

    // ---- content directory ------------------------------------------------

    /// Record newly committed hashes everywhere the cluster looks: the
    /// local replica, the router's shared view, and every peer's replica
    /// (gossip).
    fn publish_content(&mut self, plane: Plane, hashes: Vec<BlockHash>) {
        if hashes.is_empty() {
            return;
        }
        match plane {
            Plane::Kv => self.dir_kv.publish(self.idx, &hashes),
            Plane::Img => self.dir_img.publish(self.idx, &hashes),
        }
        {
            let mut s = self.shared_dir.lock().unwrap();
            match plane {
                Plane::Kv => s.kv.publish(self.idx, &hashes),
                Plane::Img => s.img.publish(self.idx, &hashes),
            }
        }
        for (i, (tx, _)) in self.peers.iter().enumerate() {
            if i != self.idx {
                let _ = tx.send(Msg::PublishContent {
                    idx: self.idx,
                    plane,
                    hashes: hashes.clone(),
                });
            }
        }
    }

    /// The retraction mirror of [`RealInstance::publish_content`].
    fn retract_content(&mut self, plane: Plane, hashes: Vec<BlockHash>) {
        if hashes.is_empty() {
            return;
        }
        match plane {
            Plane::Kv => self.dir_kv.retract(self.idx, &hashes),
            Plane::Img => self.dir_img.retract(self.idx, &hashes),
        }
        {
            let mut s = self.shared_dir.lock().unwrap();
            match plane {
                Plane::Kv => s.kv.retract(self.idx, &hashes),
                Plane::Img => s.img.retract(self.idx, &hashes),
            }
        }
        for (i, (tx, _)) in self.peers.iter().enumerate() {
            if i != self.idx {
                let _ = tx.send(Msg::RetractContent {
                    idx: self.idx,
                    plane,
                    hashes: hashes.clone(),
                });
            }
        }
    }

    /// Drain eviction logs into directory retractions (runs every loop
    /// iteration; evictions happen inside reserve/admit grows).
    fn sync_directory(&mut self) {
        let kv = self.kv.drain_evicted();
        self.retract_content(Plane::Kv, kv);
        let img = self.img.drain_evicted();
        self.retract_content(Plane::Img, img);
    }

    /// Give up on peer-pulls past their deadline: the request falls back
    /// to the normal encode path (counted as a stale pull).
    fn expire_fetches(&mut self) {
        let now = self.now();
        let expired: Vec<u64> = self
            .fetch_parked
            .iter()
            .filter(|(_, (_, deadline))| now > *deadline)
            .map(|(id, _)| *id)
            .collect();
        for id in expired {
            let (st, _) = self.fetch_parked.remove(&id).expect("just listed");
            self.shared_dir.lock().unwrap().stale_pulls += 1;
            self.queues.push_waiting(st);
        }
    }

    /// Source side of a peer-pull: gather the advertised embedding blocks
    /// for the requester, or report a miss if any were evicted meanwhile.
    fn serve_fetch(&mut self, req_id: RequestId, dst: usize, hashes: &[BlockHash]) {
        let mut data = Vec::new();
        let mut ok = !hashes.is_empty();
        for h in hashes {
            let Some(b) = self.img.block_of(h) else {
                ok = false;
                break;
            };
            let bs = self.img.block_size() as u32;
            let slots: Vec<u32> = (b * bs..(b + 1) * bs).collect();
            data.extend_from_slice(&self.img_store.gather(0, &slots));
        }
        let _ = self.peers[dst].0.send(Msg::CacheData {
            req_id,
            data: ok.then_some(data),
        });
    }

    /// Target side of a peer-pull reply: install the embedding, mark the
    /// encode as served from cache, and release the request to the
    /// scheduler. A miss (or a request that already moved on) falls back
    /// to encoding.
    fn receive_cache_data(&mut self, req_id: RequestId, data: Option<Vec<f32>>) {
        let Some((mut st, _)) = self.fetch_parked.remove(&req_id.0) else {
            return; // timed out earlier; already back on the encode path
        };
        let img_tokens = st.spec.image_tokens();
        // distinguish a genuinely stale advertisement (the source had
        // nothing to send) from local pool pressure (valid data arrived
        // but our own image pool cannot hold it): only the former is
        // directory staleness
        let mut stale = false;
        let installed = match data {
            Some(rows) if rows.len() == img_tokens * self.img_store.hidden() => {
                match self.img.grow(req_id, img_tokens) {
                    Ok(()) => {
                        self.img
                            .slot_mapping_into(req_id, &mut self.scratch_slots)
                            .expect("table grown above");
                        let h = self.img_store.hidden();
                        for (i, &slot) in self.scratch_slots.iter().enumerate() {
                            self.img_store.write_token(0, slot, &rows[i * h..(i + 1) * h]);
                        }
                        let hashes: &[BlockHash] = self
                            .data
                            .get(&req_id.0)
                            .map_or(&[], |d| d.img_hashes.as_slice());
                        let new = self.img.commit_hashes(req_id, hashes);
                        self.publish_content(Plane::Img, new);
                        true
                    }
                    Err(_) => false, // genuine pool pressure: encode instead
                }
            }
            _ => {
                stale = true;
                false
            }
        };
        {
            let mut s = self.shared_dir.lock().unwrap();
            if installed {
                s.peer_pulls += 1;
            } else if stale {
                s.stale_pulls += 1;
            }
        }
        if installed {
            st.cached_images = st.spec.num_images;
            st.encoded_images = st.spec.num_images;
        }
        self.queues.push_waiting(st);
    }

    // ---- message handling ------------------------------------------------

    fn handle(&mut self, msg: Msg) -> bool {
        match msg {
            Msg::Shutdown => return false,
            Msg::Submit(p) => {
                let now = self.now();
                let mut lc = Lifecycle::new(p.spec.arrival);
                lc.arrival = p.spec.arrival;
                let kv_hashes = content::token_kv_hashes(
                    &p.tokens,
                    p.spec.image_hash,
                    p.spec.image_tokens(),
                    self.kv.block_size(),
                );
                let img_hashes = match p.spec.image_hash {
                    Some(h) => content::image_block_hashes(h, p.spec.num_images.max(1)),
                    None => Vec::new(),
                };
                let mut st = ReqState::new(p.spec.clone());
                // image-embedding reuse: pin a cached copy and skip the
                // encode. Only when this instance can also prefill — an
                // E-only instance re-encodes rather than stranding a
                // prefill-stage request it cannot serve.
                if st.spec.has_image() && self.mask.prefill && !self.img.has_request(st.spec.id)
                {
                    if let Ok(cached) =
                        self.img.acquire_prefix(st.spec.id, &img_hashes, st.spec.image_tokens())
                    {
                        let imgs = cached / st.spec.tokens_per_image.max(1);
                        st.cached_images = imgs;
                        st.encoded_images = st.encoded_images.max(imgs);
                    }
                }
                // KV-prefix reuse in COMPUTE: when the artifacts can resume
                // mid-prompt (`prefill_kv_s*` buckets), pin the cached
                // prompt prefix now and pre-advance prefill past it — the
                // prefill exec path then dispatches a suffix-sized resumed
                // prefill over the pinned pool rows. Without resume
                // support nothing is pinned or advanced here, keeping
                // behaviour bit-identical to full prefill (the prefix is
                // still pinned later at reserve() for delta migration).
                let mut resume_ctx = 0usize;
                if self.mask.prefill
                    && self.device.supports_prefill_resume()
                    && !self.kv.has_request(st.spec.id)
                {
                    if let Ok(cached) = self.kv.acquire_prefix(
                        st.spec.id,
                        &kv_hashes,
                        st.spec.prefill_tokens().saturating_sub(1),
                    ) {
                        if cached > 0
                            && self
                                .device
                                .plan_prefill_resume(
                                    cached,
                                    st.spec.prefill_tokens(),
                                    st.spec.has_image(),
                                )
                                .is_some()
                        {
                            // the pinned rows are live in the pool: prefill
                            // starts mid-prompt, and only the suffix counts
                            // against the scheduler's token budget
                            st.cached_prefill = cached;
                            st.prefilled = cached;
                            resume_ctx = cached;
                        }
                    }
                }
                self.data.insert(
                    p.spec.id.0,
                    ReqData {
                        tokens: p.tokens,
                        pixels: p.pixels,
                        sampler: Sampler::new(p.sampling.clone()),
                        generated: Vec::new(),
                        lifecycle: lc,
                        ctx_len: resume_ctx,
                        ready_since: now,
                        kv_hashes,
                        img_hashes: img_hashes.clone(),
                    },
                );
                // fetch-over-recompute: the embedding is not cached here
                // but a peer advertises it — pull the cached blocks over
                // the channel instead of re-running the vision tower
                // (copying rows is orders of magnitude cheaper). The
                // request parks until the data (or a miss) comes back.
                if st.encoded_images < st.spec.num_images
                    && self.mask.prefill
                    && p.spec.image_hash.is_some()
                {
                    if let Some((src, blocks)) =
                        self.dir_img.best_holder(&img_hashes, self.idx)
                    {
                        if blocks >= img_hashes.len() {
                            let req_id = st.spec.id;
                            let _ = self.peers[src].0.send(Msg::FetchContent {
                                req_id,
                                dst: self.idx,
                                hashes: img_hashes,
                            });
                            // generous deadline: the source answers from
                            // its single-threaded loop, so a reply can sit
                            // behind a couple of batch steps — only give
                            // up when it is clearly not coming
                            self.fetch_parked.insert(req_id.0, (st, now + 1.0));
                            return true;
                        }
                    }
                }
                self.queues.push_waiting(st);
            }
            Msg::Offer(o) => self.inbound.push(*o),
            Msg::Pull(p) => self.serve_pull(p),
            Msg::Payload(pl) => self.receive_payload(*pl),
            Msg::PublishContent { idx, plane, hashes } => match plane {
                Plane::Kv => self.dir_kv.publish(idx, &hashes),
                Plane::Img => self.dir_img.publish(idx, &hashes),
            },
            Msg::RetractContent { idx, plane, hashes } => match plane {
                Plane::Kv => self.dir_kv.retract(idx, &hashes),
                Plane::Img => self.dir_img.retract(idx, &hashes),
            },
            Msg::FetchContent { req_id, dst, hashes } => {
                self.serve_fetch(req_id, dst, &hashes)
            }
            Msg::CacheData { req_id, data } => self.receive_cache_data(req_id, data),
            Msg::Reconfigure(mask) => self.drain_to = Some(mask),
            Msg::CancelDrain => self.drain_to = None,
            Msg::PeerMask { idx, mask } => {
                if let Some(peer) = self.peers.get_mut(idx) {
                    peer.1 = mask;
                }
            }
            Msg::PeerDrain { idx, draining } => {
                if let Some(f) = self.peer_draining.get_mut(idx) {
                    *f = draining;
                }
            }
            Msg::Release(r) => {
                // step 4: target confirmed receipt; free everything local
                self.release_caches(r.req_id);
                self.data.remove(&r.req_id.0);
                self.queues.remove_running(r.req_id);
            }
        }
        true
    }

    /// Step 2 (we are the target): admit queued offers when capacity
    /// allows, and report whatever payload content our cache already
    /// holds so the source only ships the delta.
    fn admit_offers(&mut self) {
        let mut i = 0;
        while i < self.inbound.len() {
            if self.can_admit(&self.inbound[i].req) {
                let offer = self.inbound.remove(i);
                let (kv_have_tokens, img_have) = self.reserve_offer(&offer);
                let src = offer.src;
                let req_id = offer.req.spec.id;
                self.pending_in.insert(req_id.0, (offer, kv_have_tokens));
                let _ = self.peers[src].0.send(Msg::Pull(Pull {
                    req_id,
                    dst: self.idx,
                    kv_have_tokens,
                    img_have,
                }));
            } else {
                i += 1;
            }
        }
    }

    /// Step 3 (we are the source): ship only the payload the target is
    /// missing (delta transfer).
    fn serve_pull(&mut self, p: Pull) {
        let id = p.req_id;
        let Some(state) = self.queues.get_running(id) else {
            return;
        };
        let kind = if state.prefill_remaining() > 0 {
            MigrationKind::EncodeToPrefill
        } else {
            MigrationKind::PrefillToDecode
        };
        let payload = match kind {
            MigrationKind::EncodeToPrefill => {
                let img_embed = if p.img_have {
                    None // target-side cache hit: nothing to ship
                } else {
                    self.img
                        .slot_mapping_into(id, &mut self.scratch_slots)
                        .expect("img allocated");
                    Some(self.img_store.gather(0, &self.scratch_slots))
                };
                Payload {
                    req_id: id,
                    kind,
                    img_embed,
                    kv_planes: None,
                    kv_tokens: 0,
                    kv_from: 0,
                }
            }
            MigrationKind::PrefillToDecode => {
                let d = self.data.get(&id.0).expect("data present");
                let valid = d.ctx_len;
                let from = p.kv_have_tokens.min(valid);
                let bs = self.kv.block_size();
                let table = self.kv.table(id).expect("kv allocated");
                self.scratch_slots.clear();
                self.scratch_slots
                    .extend((from..valid).map(|pos| table.slot_of(pos, bs).unwrap()));
                let planes = (0..self.kv_store.num_planes())
                    .map(|pl| self.kv_store.gather(pl, &self.scratch_slots))
                    .collect();
                Payload {
                    req_id: id,
                    kind,
                    img_embed: None,
                    kv_planes: Some(planes),
                    kv_tokens: valid,
                    kv_from: from,
                }
            }
        };
        let _ = self.peers[p.dst].0.send(Msg::Payload(Box::new(payload)));
    }

    /// Step 3 receive + step 4 (we are the target).
    fn receive_payload(&mut self, pl: Payload) {
        let id = pl.req_id;
        let Some((offer, kv_have)) = self.pending_in.remove(&id.0) else { return };
        let now = self.now();
        let mut lc = offer.lifecycle;
        let phase = match pl.kind {
            MigrationKind::EncodeToPrefill => Phase::EpMigration,
            MigrationKind::PrefillToDecode => Phase::PdMigration,
        };
        let dur = offer.offered_at.elapsed().as_secs_f64();
        lc.add_phase(phase, dur);
        self.obs.tracer.lock().unwrap().span(
            SpanKind::from_phase(phase),
            self.idx,
            id.0,
            now - dur,
            now,
            kv_have as u64,
        );
        self.obs.migrations.inc();

        let mut state = offer.req;
        state.migrating = false;
        let mut ctx_len = 0;
        match pl.kind {
            MigrationKind::EncodeToPrefill => {
                // None = our cache already held the embedding (delta pull)
                if let Some(embed) = pl.img_embed {
                    self.img
                        .slot_mapping_into(id, &mut self.scratch_slots)
                        .expect("img reserved at admit");
                    let h = self.img_store.hidden();
                    for (i, &slot) in self.scratch_slots.iter().enumerate() {
                        self.img_store.write_token(0, slot, &embed[i * h..(i + 1) * h]);
                    }
                }
                // the embedding now lives here: publish it for reuse
                let new = self.img.commit_hashes(id, &offer.img_block_hashes);
                self.publish_content(Plane::Img, new);
                // the KV-prefix blocks our cache held at admit time become
                // real compute savings: when the artifacts can resume
                // mid-prompt, prefill starts after the cached prefix
                // instead of re-running the whole prompt (this is where
                // the directory's KV delta pays off in FLOPs, not just
                // transfer bytes)
                if kv_have > 0
                    && self
                        .device
                        .plan_prefill_resume(
                            kv_have,
                            state.spec.prefill_tokens(),
                            state.spec.has_image(),
                        )
                        .is_some()
                {
                    state.cached_prefill = state.cached_prefill.max(kv_have);
                    state.prefilled = state.prefilled.max(kv_have);
                    ctx_len = kv_have;
                }
            }
            MigrationKind::PrefillToDecode => {
                let planes = pl.kv_planes.expect("pd payload has kv");
                ctx_len = pl.kv_tokens;
                let bs = self.kv.block_size();
                let table = self.kv.table(id).expect("kv reserved at admit");
                // positions below kv_from were a local cache hit and were
                // never transferred
                let from = pl.kv_from.min(ctx_len);
                self.scratch_slots.clear();
                self.scratch_slots
                    .extend((from..ctx_len).map(|pos| table.slot_of(pos, bs).unwrap()));
                for (p, plane) in planes.into_iter().enumerate() {
                    self.kv_store.scatter(p, &self.scratch_slots, &plane);
                }
                // the prompt-prefix KV now lives here: publish it
                let new = self.kv.commit_hashes(id, &offer.kv_block_hashes);
                self.publish_content(Plane::Kv, new);
            }
        }

        self.data.insert(
            id.0,
            ReqData {
                tokens: offer.tokens,
                pixels: None,
                sampler: Sampler::new(offer.sampling),
                generated: offer.generated,
                lifecycle: lc,
                ctx_len,
                ready_since: now,
                kv_hashes: offer.kv_block_hashes,
                img_hashes: offer.img_block_hashes,
            },
        );
        self.queues.push_running(state);
        // step 4: tell the source to release
        let _ = self.peers[offer.src].0.send(Msg::Release(Release { req_id: id }));
    }

    /// Hand a request whose next stage we don't serve to a peer (step 1).
    fn migrate_out(&mut self, id: RequestId) {
        let Some(state) = self.queues.get_running(id) else {
            return;
        };
        let state = state.clone();
        let next = state.stage();
        let candidates: Vec<usize> = self
            .peers
            .iter()
            .enumerate()
            .filter(|(i, (_, m))| *i != self.idx && m.serves(next))
            .map(|(i, _)| i)
            .collect();
        let Some(dst) = pick_peer(&mut self.router, &candidates, &self.peer_draining) else {
            return; // incomplete cluster: request is stranded
        };
        let kind = if next == Stage::Prefill {
            MigrationKind::EncodeToPrefill
        } else {
            MigrationKind::PrefillToDecode
        };
        self.queues.find_running(id).expect("looked up above").migrating = true;
        let d = self.data.get(&id.0).expect("data present");
        let offer = Offer {
            req: {
                let mut s = state.clone();
                s.migrating = false;
                s
            },
            kind,
            tokens: d.tokens.clone(),
            sampling: d.sampler.params().clone(),
            generated: d.generated.clone(),
            img_embed_floats: state.spec.image_tokens() * self.device.cfg().hidden,
            kv_tokens: d.ctx_len,
            kv_block_hashes: d.kv_hashes.clone(),
            img_block_hashes: d.img_hashes.clone(),
            src: self.idx,
            offered_at: Instant::now(),
            lifecycle: d.lifecycle.clone(),
        };
        let _ = self.peers[dst].0.send(Msg::Offer(Box::new(offer)));
    }

    // ---- batch execution ---------------------------------------------------

    /// Build and execute one batch; returns false if there was nothing to do.
    fn step(&mut self) -> Result<bool> {
        self.admit_offers();

        let mut sched = std::mem::replace(&mut self.sched, self.policy.make(self.mask));
        let batch = {
            let kv = &self.kv;
            let img = &self.img;
            let kv_bs = kv.block_size().max(1);
            let img_bs = img.block_size().max(1);
            let kv_avail = kv.available_blocks();
            let img_avail = img.available_blocks();
            let mask = self.mask;
            let mut kv_used = 0usize;
            let mut img_used = 0usize;
            let mut admit = |r: &ReqState| {
                // already-pinned (cached-prefix) blocks cost nothing;
                // evictable cached blocks count as capacity
                let kv_need = crate::util::ceil_div(kv_tokens_needed_mask(mask, r), kv_bs)
                    .saturating_sub(kv.held_blocks(r.spec.id));
                let img_need = crate::util::ceil_div(img_tokens_needed_mask(mask, r), img_bs)
                    .saturating_sub(img.held_blocks(r.spec.id));
                if kv_used + kv_need <= kv_avail && img_used + img_need <= img_avail {
                    kv_used += kv_need;
                    img_used += img_need;
                    true
                } else {
                    false
                }
            };
            sched.build_batch(&mut self.queues, &self.budgets, &mut admit)
        };
        self.sched = sched;

        for i in 0..self.queues.running_len() {
            let r = self.queues.running()[i].clone();
            self.reserve(&r);
        }

        let started = self.now();
        let mut did_work = false;
        self.obs.batch_occupancy.set(batch.items.len() as f64);

        // ---------------- encode (vision stream) ----------------
        let encode_items: Vec<(RequestId, usize)> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::Encode { images } => Some((*id, *images)),
                _ => None,
            })
            .collect();
        if !encode_items.is_empty() {
            let mut pixels = Vec::new();
            for (id, n) in &encode_items {
                let d = self.data.get(&id.0).ok_or_else(|| anyhow!("no data for {id}"))?;
                let px = d.pixels.clone().ok_or_else(|| anyhow!("{id} has no pixels"))?;
                for _ in 0..*n {
                    pixels.push(px.clone()); // one image per request here
                }
            }
            let embeds = self.device.encode(pixels)?;
            let mut k = 0;
            let now = self.now();
            for (id, n) in &encode_items {
                self.img
                    .slot_mapping_into(*id, &mut self.scratch_slots)
                    .expect("img reserved");
                let h = self.img_store.hidden();
                let embed = &embeds[k];
                for (i, &slot) in self.scratch_slots.iter().enumerate() {
                    self.img_store.write_token(0, slot, &embed[i * h..(i + 1) * h]);
                }
                k += n;
                // publish the fresh embedding for cross-request reuse
                let img_hashes: &[BlockHash] =
                    self.data.get(&id.0).map_or(&[], |d| d.img_hashes.as_slice());
                let new = self.img.commit_hashes(*id, img_hashes);
                self.publish_content(Plane::Img, new);
                let d = self.data.get_mut(&id.0).unwrap();
                let rs = d.ready_since;
                d.lifecycle.add_phase(Phase::EncodeQueue, (started - rs).max(0.0));
                d.lifecycle.add_phase(Phase::EncodeExec, now - started);
                d.ready_since = now;
                {
                    let mut t = self.obs.tracer.lock().unwrap();
                    t.span(SpanKind::EncodeQueue, self.idx, id.0, rs.min(started), started, 0);
                    t.span(SpanKind::EncodeExec, self.idx, id.0, started, now, *n as u64);
                }
                if let Some(r) = self.queues.find_running(*id) {
                    r.encoded_images += n;
                }
            }
            did_work = true;
        }

        // ---------------- prefill (language stream) ----------------
        let prefill_items: Vec<(RequestId, usize)> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::PrefillChunk { tokens, .. } => Some((*id, *tokens)),
                _ => None,
            })
            .collect();
        // pool snapshot shared by every resumed prefill in this batch,
        // taken lazily: a resume plan only exists for prefix content
        // committed BEFORE this batch (submit/admit-time acquire), so the
        // rows it reads cannot be written by this loop — one copy serves
        // all items instead of a multi-MB copy per request
        let mut resume_pools: Option<(Arc<Vec<f32>>, Arc<Vec<f32>>)> = None;
        for (id, _tokens) in &prefill_items {
            let (spec, has_image, ctx) = {
                let r = self
                    .queues
                    .find_running(*id)
                    .ok_or_else(|| anyhow!("prefill req {id} missing"))?;
                (r.spec.clone(), r.spec.has_image(), r.prefilled)
            };
            // prefill-with-prefix: a cached context (pinned at submit /
            // payload-landing) resumes mid-prompt — only the SUFFIX is
            // computed and scattered, against a suffix-sized artifact
            // bucket. `ctx == 0` or no feasible plan = full prefill,
            // bit-identical to the pre-resume engine.
            let resume = if ctx > 0 {
                self.device.plan_prefill_resume(ctx, spec.prefill_tokens(), has_image)
            } else {
                None
            };
            let (logits, valid_len) = if let Some(plan) = resume {
                // suffix text tokens: position ctx maps to prompt token
                // ctx - image_tokens (the plan guarantees the prefix
                // covers the image region, so no embedding is needed)
                let suffix: Vec<u32> = {
                    let d = self.data.get(&id.0).ok_or_else(|| anyhow!("no data for {id}"))?;
                    d.tokens[ctx - spec.image_tokens()..].to_vec()
                };
                // suffix slots computed up front so only the block list —
                // not the whole table — needs an owned copy for the RPC
                let bs = self.kv.block_size();
                let blocks: Vec<u32> = {
                    let table = self.kv.table(*id).expect("kv reserved");
                    self.scratch_slots.clear();
                    self.scratch_slots.extend(
                        (ctx..ctx + plan.suffix_len).map(|p| table.slot_of(p, bs).unwrap()),
                    );
                    table.blocks.clone()
                };
                let (k_pool, v_pool) = resume_pools
                    .get_or_insert_with(|| {
                        let (k, v) = self.lm_pools();
                        (Arc::new(k), Arc::new(v))
                    })
                    .clone();
                let out = self.device.prefill_resume(plan, suffix, blocks, k_pool, v_pool)?;
                // scatter ONLY the suffix rows; the prefix rows are the
                // shared cached blocks, already live in the pool
                let layers = self.device.cfg().layers;
                for (l, (k, v)) in out.k_suffix.iter().zip(out.v_suffix.iter()).enumerate() {
                    self.kv_store.scatter(l, &self.scratch_slots, k);
                    self.kv_store.scatter(layers + l, &self.scratch_slots, v);
                }
                (out.logits, ctx + out.suffix_len)
            } else {
                let img_embed = if has_image {
                    self.img.slot_mapping_into(*id, &mut self.scratch_slots)?;
                    Some(self.img_store.gather(0, &self.scratch_slots))
                } else {
                    None
                };
                let tokens = self.data.get(&id.0).unwrap().tokens.clone();
                let out = self.device.prefill(tokens, img_embed)?;
                // scatter KV into our paged store
                let bs = self.kv.block_size();
                let table = self.kv.table(*id).expect("kv reserved");
                self.scratch_slots.clear();
                self.scratch_slots
                    .extend((0..out.valid_len).map(|p| table.slot_of(p, bs).unwrap()));
                let layers = self.device.cfg().layers;
                for (l, (k, v)) in out.k.iter().zip(out.v.iter()).enumerate() {
                    self.kv_store.scatter(l, &self.scratch_slots, k);
                    self.kv_store.scatter(layers + l, &self.scratch_slots, v);
                }
                (out.logits, out.valid_len)
            };
            let now = self.now();

            // the prompt-region KV is final: publish it for prefix reuse
            let kv_hashes: &[BlockHash] =
                self.data.get(&id.0).map_or(&[], |d| d.kv_hashes.as_slice());
            let new = self.kv.commit_hashes(*id, kv_hashes);
            self.publish_content(Plane::Kv, new);

            // first output token comes from the prefill logits
            let d = self.data.get_mut(&id.0).unwrap();
            let tok = d.sampler.sample(&logits);
            d.generated.push(tok);
            d.ctx_len = valid_len;
            let rs = d.ready_since;
            d.lifecycle.add_phase(Phase::PrefillQueue, (started - rs).max(0.0));
            d.lifecycle.add_phase(Phase::PrefillExec, now - started);
            d.lifecycle.record_token(now);
            d.ready_since = now;
            {
                let mut t = self.obs.tracer.lock().unwrap();
                t.span(SpanKind::PrefillQueue, self.idx, id.0, rs.min(started), started, 0);
                t.span(SpanKind::PrefillExec, self.idx, id.0, started, now, valid_len as u64);
            }

            // image embeddings consumed
            if self.img.has_request(*id) {
                self.img.free(*id).unwrap();
            }
            let r = self.queues.find_running(*id).unwrap();
            r.prefilled = spec.prefill_tokens();
            r.decoded = 1;
            did_work = true;
        }

        // ---------------- decode (language stream, batched) ----------------
        let decode_ids: Vec<RequestId> = batch
            .items
            .iter()
            .filter_map(|(id, w)| match w {
                TaskWork::DecodeToken { .. } => Some(*id),
                _ => None,
            })
            .collect();
        if !decode_ids.is_empty() {
            let mut inputs = Vec::with_capacity(decode_ids.len());
            for id in &decode_ids {
                let d = self.data.get(&id.0).ok_or_else(|| anyhow!("no data for {id}"))?;
                let last = *d.generated.last().expect("decode implies a prior token");
                let table = self.kv.table(*id).expect("kv reserved");
                inputs.push(DecodeInput {
                    token: last,
                    position: d.ctx_len,
                    block_table: table.blocks.clone(),
                    seq_len: d.ctx_len,
                });
            }
            let layers = self.device.cfg().layers;
            let (k_pool, v_pool) = self.lm_pools();
            let out = self.device.decode(inputs, k_pool, v_pool)?;
            let now = self.now();
            for (i, id) in decode_ids.iter().enumerate() {
                // write the input token's KV at its slot, then advance
                let d = self.data.get_mut(&id.0).unwrap();
                let pos = d.ctx_len;
                let table = self.kv.table(*id).unwrap().clone();
                let slot = table
                    .slot_of(pos, self.kv.block_size())
                    .expect("reserved through output length");
                let h = self.device.cfg().hidden;
                for l in 0..layers {
                    self.kv_store
                        .write_token(l, slot, &out.k_new[i][l * h..(l + 1) * h]);
                    self.kv_store
                        .write_token(layers + l, slot, &out.v_new[i][l * h..(l + 1) * h]);
                }
                let tok = d.sampler.sample(&out.logits[i]);
                d.generated.push(tok);
                d.ctx_len += 1;
                let rs = d.ready_since;
                d.lifecycle.add_phase(Phase::DecodeQueue, (started - rs).max(0.0));
                d.lifecycle.add_phase(Phase::DecodeExec, now - started);
                d.lifecycle.record_token(now);
                d.ready_since = now;
                {
                    let mut t = self.obs.tracer.lock().unwrap();
                    t.span(SpanKind::DecodeQueue, self.idx, id.0, rs.min(started), started, 0);
                    t.span(SpanKind::DecodeExec, self.idx, id.0, started, now, 1);
                }
                let r = self.queues.find_running(*id).unwrap();
                r.decoded += 1;
            }
            did_work = true;
        }

        // ---------------- post-batch transitions ----------------
        let ids: Vec<RequestId> = self.queues.running().iter().map(|r| r.spec.id).collect();
        for id in ids {
            let Some(r) = self.queues.find_running(id) else { continue };
            if r.migrating {
                continue;
            }
            if r.finished() {
                self.finish(id);
            } else if !self.mask.serves(r.stage()) {
                self.migrate_out(id);
            }
        }
        Ok(did_work)
    }

    /// Drain-then-flip: once we hold no requests at all, assume the new
    /// role and tell the controller (which updates peers and routing).
    /// Caches are fixed-size pools in real mode, so no resize is needed.
    fn maybe_flip(&mut self) {
        let Some(to) = self.drain_to else { return };
        let empty = self.queues.waiting_is_empty()
            && self.queues.running_is_empty()
            && self.inbound.is_empty()
            && self.pending_in.is_empty()
            && self.fetch_parked.is_empty();
        if !empty {
            return;
        }
        let from = self.mask;
        self.mask = to;
        self.sched = self.policy.make(to);
        self.drain_to = None;
        self.obs
            .tracer
            .lock()
            .unwrap()
            .mark(SpanKind::RoleFlip, self.idx, self.now(), mask_bits(to));
        crate::util::logging::log(
            crate::util::logging::Level::Info,
            "instance",
            format_args!(
                "instance {} reconfigured {} -> {}",
                self.idx,
                from.label(),
                to.label()
            ),
        );
        if let Some(tx) = &self.ctrl {
            let _ = tx.send(ControlEvent::FlipDone { idx: self.idx, mask: to });
        }
    }

    /// Forward waiting requests this instance can no longer serve. Closes
    /// the submit/flip race: `submit` routes under a snapshot of the
    /// layout, so a request can arrive just after our role changed; the
    /// scheduler would never admit it and it would wait forever. Only the
    /// waiting queue needs this — running requests at an unserved stage
    /// (e.g. an Offer admitted right after a flip) are migrated out by
    /// `step()`'s post-batch transition loop, which runs every iteration.
    fn reroute_unserved(&mut self) {
        if self.ctrl.is_none() {
            return; // static layout: masks never change, nothing can strand
        }
        let Self {
            queues,
            mask,
            peers,
            peer_draining,
            router,
            idx,
            data,
            kv,
            img,
            ..
        } = self;
        let (mask, idx) = (*mask, *idx);
        queues.reroute_unserved(
            |stage| mask.serves(stage),
            |r| {
                let stage = r.stage();
                let candidates: Vec<usize> = peers
                    .iter()
                    .enumerate()
                    .filter(|(j, (_, m))| *j != idx && m.serves(stage))
                    .map(|(j, _)| j)
                    .collect();
                if candidates.is_empty() {
                    return Some(r); // incomplete cluster: keep waiting here
                }
                let Some(dst) = pick_peer(router, &candidates, peer_draining) else {
                    return Some(r);
                };
                // drop any cache prefix pinned at submit before it leaves
                release_cache_pair(kv, img, r.spec.id);
                let Some(d) = data.remove(&r.spec.id.0) else { return None };
                // a waiting request has made no progress: re-submit it whole
                let prepared = PreparedRequest {
                    spec: r.spec,
                    tokens: d.tokens,
                    pixels: d.pixels,
                    sampling: d.sampler.params().clone(),
                    epoch: 0,
                };
                let _ = peers[dst].0.send(Msg::Submit(Box::new(prepared)));
                None
            },
        );
    }

    /// Periodic queue-depth sample: per-stage backlog gauges always, plus
    /// the controller's estimator feed when the elastic plane is on.
    fn maybe_sample(&mut self) {
        let now = self.now();
        if now - self.last_sample < 0.05 {
            return;
        }
        self.last_sample = now;
        let mut s = InstanceSample::idle(self.mask, self.drain_to.is_some());
        // migrating requests are counted at the pulling side
        for r in self
            .queues
            .iter_waiting()
            .chain(self.queues.running().iter().filter(|r| !r.migrating))
        {
            s.add_req(r);
        }
        for o in &self.inbound {
            s.add_req(&o.req);
        }
        for (o, _) in self.pending_in.values() {
            s.add_req(&o.req);
        }
        for (st, _) in self.fetch_parked.values() {
            s.add_req(st);
        }
        self.obs.queue_depth[0].set(s.encode_backlog);
        self.obs.queue_depth[1].set(s.prefill_backlog);
        self.obs.queue_depth[2].set(s.decode_backlog);
        if let Some(tx) = &self.ctrl {
            let _ = tx.send(ControlEvent::Sample { idx: self.idx, sample: s });
        }
    }

    fn finish(&mut self, id: RequestId) {
        if self.queues.remove_running(id).is_none() {
            return;
        }
        self.release_caches(id);
        if let Some(mut d) = self.data.remove(&id.0) {
            d.lifecycle.finished_at = Some(self.now());
            if let Some(t) = d.lifecycle.ttft() {
                self.obs.ttft.lock().unwrap().record(t);
            }
            {
                let mut h = self.obs.tpot.lock().unwrap();
                for t in d.lifecycle.tpots() {
                    h.record(t);
                }
            }
            self.obs.finished.inc();
            // tee the finished latencies into the controller's estimator
            // (the results channel alone never reaches it)
            if let Some(tx) = &self.ctrl {
                let _ = tx.send(ControlEvent::Finished(Box::new(d.lifecycle.clone())));
            }
            let text = self.tokenizer.decode(&d.generated);
            let _ = self.results.send(ServeResult {
                id,
                tokens: d.generated,
                text,
                lifecycle: d.lifecycle,
                error: None,
            });
        }
    }

    /// A batch failed `retry.max_attempts` times in a row: stop silently
    /// spinning and dead-letter every non-migrating running request —
    /// each gets a structured error response carrying whatever tokens it
    /// generated before the failure, its caches are released, and the
    /// scheduler forgets it. Waiting requests are untouched (they were
    /// not in the failing batch) and migrating requests belong to their
    /// pull target now.
    fn dead_letter_running(&mut self, reason: &str) {
        let ids: Vec<RequestId> = self
            .queues
            .running()
            .iter()
            .filter(|r| !r.migrating)
            .map(|r| r.spec.id)
            .collect();
        for id in ids {
            self.queues.remove_running(id);
            self.release_caches(id);
            let Some(mut d) = self.data.remove(&id.0) else { continue };
            d.lifecycle.finished_at = Some(self.now());
            self.obs.dead_letters.inc();
            let text = self.tokenizer.decode(&d.generated);
            let _ = self.results.send(ServeResult {
                id,
                tokens: d.generated,
                text,
                lifecycle: d.lifecycle,
                error: Some(format!("instance {}: {reason}", self.idx)),
            });
        }
    }

    fn run(mut self, rx: Receiver<Msg>) {
        loop {
            // liveness: the supervisor reads this stamp; one store per
            // loop pass (a stalled or wedged thread goes silent and gets
            // marked dead after `SupervisorConfig::dead_after`)
            self.heartbeat
                .store(self.epoch.elapsed().as_millis() as u64, Ordering::Relaxed);
            // drain everything pending
            loop {
                match rx.try_recv() {
                    Ok(msg) => {
                        if !self.handle(msg) {
                            return;
                        }
                    }
                    Err(_) => break,
                }
            }
            self.maybe_flip();
            self.reroute_unserved();
            self.expire_fetches();
            self.maybe_sample();
            let worked = match self.step() {
                Ok(w) => {
                    self.failed_steps = 0;
                    w
                }
                Err(e) => {
                    self.obs.batch_failures.inc();
                    self.failed_steps += 1;
                    crate::util::logging::log(
                        crate::util::logging::Level::Error,
                        "instance",
                        format_args!(
                            "instance {} batch failed (attempt {}/{}): {e:#}",
                            self.idx, self.failed_steps, self.retry.max_attempts
                        ),
                    );
                    if self.failed_steps >= self.retry.max_attempts {
                        // the batch is not transient: answer its requests
                        // with structured errors instead of spinning on
                        // the same failure forever
                        self.dead_letter_running(&format!(
                            "batch failed {} times: {e:#}",
                            self.failed_steps
                        ));
                        self.failed_steps = 0;
                    } else {
                        std::thread::sleep(Duration::from_millis(
                            self.retry.delay_ms(self.failed_steps - 1),
                        ));
                    }
                    false
                }
            };
            // reserving/admitting may have evicted cached blocks: retract
            // their advertisements before peers decide on them again
            self.sync_directory();
            if !worked {
                // idle: block for the next message (with a timeout so queued
                // offers get re-checked for capacity)
                match rx.recv_timeout(Duration::from_millis(5)) {
                    Ok(msg) => {
                        if !self.handle(msg) {
                            return;
                        }
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => return,
                }
            }
        }
    }
}

/// Round-robin over `candidates`, skipping mid-drain peers; falls back to
/// them when no one else is eligible, so work is never dropped just
/// because a reconfiguration is in flight. Returns the chosen instance
/// index (the real-mode analogue of the simulator's routing).
fn pick_peer(router: &mut Router, candidates: &[usize], draining: &[bool]) -> Option<usize> {
    let zeros = vec![0.0; candidates.len()];
    pick_peer_affinity(router, candidates, draining, &zeros)
}

/// [`pick_peer`] with per-candidate cache-affinity scores: a peer whose
/// cache likely holds this request's content wins over round-robin.
fn pick_peer_affinity(
    router: &mut Router,
    candidates: &[usize],
    draining: &[bool],
    affinity: &[f64],
) -> Option<usize> {
    if candidates.is_empty() {
        return None;
    }
    let gated = Router::gated_loads(candidates.len(), |p| {
        !draining.get(candidates[p]).copied().unwrap_or(false)
    });
    if let Some(p) = router.pick_affinity(&gated, affinity) {
        return Some(candidates[p]);
    }
    let raw = vec![0.0; candidates.len()];
    router.pick(&raw).map(|p| candidates[p])
}

/// Free a request's holdings on both cache planes (free function over the
/// split-borrowed pair so the post-flip reroute closure shares the exact
/// same release path as [`RealInstance`]'s method).
fn release_cache_pair(kv: &mut PagedCache, img: &mut PagedCache, id: RequestId) {
    if kv.has_request(id) {
        kv.free(id).unwrap();
    }
    if img.has_request(id) {
        img.free(id).unwrap();
    }
}

fn kv_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    if !(mask.prefill || mask.decode) {
        return 0;
    }
    r.spec.prefill_tokens() + if mask.decode { r.spec.output_tokens } else { 0 }
}

fn img_tokens_needed_mask(mask: StageMask, r: &ReqState) -> usize {
    let consumes = mask.encode || (mask.prefill && r.spec.has_image() && r.prefill_remaining() > 0);
    if consumes {
        r.spec.image_tokens()
    } else {
        0
    }
}

// ---------------------------------------------------------------------------
// Cluster
// ---------------------------------------------------------------------------

/// Cluster-side record of one dispatched, unfinished request.
struct Inflight {
    prepared: PreparedRequest,
    target: usize,
    epoch: u32,
    /// Already answered with a synthesized error result; kept in the map
    /// (not removed) so `collect` accepts exactly one result for the id —
    /// a zombie instance's late real finish is dropped as a duplicate.
    dead_lettered: bool,
}

/// A running disaggregated serving cluster (real execution).
pub struct RealCluster {
    senders: Vec<Sender<Msg>>,
    masks: Vec<StageMask>,
    results_rx: Option<Receiver<ServeResult>>,
    device: DeviceHandle,
    joins: Vec<JoinHandle<()>>,
    device_join: Option<JoinHandle<()>>,
    router: Router,
    tokenizer: Tokenizer,
    epoch: Instant,
    next_id: u64,
    /// The cluster content directory: block-level truth about which
    /// instance holds which content, fed by instance publish/retract
    /// gossip. Routing affinity reads it directly (replacing the old
    /// "content key -> last instance" guess).
    directory: Arc<Mutex<SharedDirectory>>,
    /// Anti-herding memory: consecutive submits a content key has ridden
    /// directory affinity. The cluster router has no live queue depths,
    /// so stickiness is *bounded*: every `AFFINITY_STREAK`-th repeat
    /// re-routes by the plain policy, spreading a hot key across
    /// instances (whose caches then warm via peer-pull) instead of
    /// herding unboundedly onto one.
    affinity_streak: FxHashMap<u64, u32>,
    /// Elastic control plane (None = static layout).
    control: Option<Arc<Mutex<ControlShared>>>,
    ctrl_stop: Arc<AtomicBool>,
    ctrl_join: Option<JoinHandle<()>>,
    /// Supervision (PR 9): per-instance death flags maintained by the
    /// supervisor thread from heartbeat ages. Routing skips dead
    /// instances; `collect` re-dispatches their in-flight work.
    dead: Vec<Arc<AtomicBool>>,
    supervisor: SupervisorConfig,
    sup_stop: Arc<AtomicBool>,
    sup_join: Option<JoinHandle<()>>,
    /// Kept so the cluster can synthesize dead-letter results onto the
    /// same channel instances deliver real finishes on.
    results_tx: Sender<ServeResult>,
    /// Dispatched-but-unfinished requests: everything needed to
    /// re-dispatch one if its target dies, plus the dispatch epoch.
    /// First-result-wins: `collect` removes the entry when a result is
    /// accepted and drops late duplicates from superseded dispatches
    /// (exactly-once finish accounting). Only maintained while the
    /// cluster still owns the results receiver — in API-server mode the
    /// instance-side dead-letter path is the safety net.
    inflight: FxHashMap<u64, Inflight>,
    retries: Arc<Counter>,
    redispatches: Arc<Counter>,
    duplicates: Arc<Counter>,
    dead_letters: Arc<Counter>,
    /// Live metrics registry (`/metrics` renders it; instances hold
    /// pre-created handles). Per-cluster, not process-global, so parallel
    /// test clusters never share instruments.
    registry: Arc<Registry>,
    /// Per-instance flight recorders; `/trace` merges their snapshots.
    tracers: Vec<Arc<Mutex<Tracer>>>,
    /// Admission counters (see `submit`).
    submitted: Arc<Counter>,
    rejected: Arc<Counter>,
}

impl RealCluster {
    /// Boot the device thread + one worker thread per instance with a
    /// static layout (the elastic controller off).
    pub fn start(artifacts_dir: &str, cluster: &ClusterSpec, policy: Policy) -> Result<RealCluster> {
        RealCluster::start_with_controller(artifacts_dir, cluster, policy, None)
    }

    /// Boot the cluster, optionally with the elastic control plane: a
    /// controller thread consumes per-instance queue samples, runs the
    /// estimator + reconfiguration policy, and drives drain-then-flip
    /// role changes over the instance mailboxes.
    pub fn start_with_controller(
        artifacts_dir: &str,
        cluster: &ClusterSpec,
        policy: Policy,
        controller: Option<ControllerConfig>,
    ) -> Result<RealCluster> {
        let (device, device_join) = spawn_device(artifacts_dir)?;
        let cfg = *device.cfg();
        let masks = cluster.instance_masks();
        let epoch = Instant::now();
        let (results_tx, results_rx) = channel();

        let mut senders = Vec::new();
        let mut receivers = Vec::new();
        for _ in &masks {
            let (tx, rx) = channel::<Msg>();
            senders.push(tx);
            receivers.push(rx);
        }

        let ctrl_stop = Arc::new(AtomicBool::new(false));
        let (ctrl_tx, ctrl_rx, control) = match &controller {
            Some(_) => {
                let (tx, rx) = channel::<ControlEvent>();
                let shared = Arc::new(Mutex::new(ControlShared {
                    masks: masks.clone(),
                    draining: vec![false; masks.len()],
                    reconfigs: 0,
                }));
                (Some(tx), Some(rx), Some(shared))
            }
            None => (None, None, None),
        };

        let budgets = Budgets {
            token_budget: 1024, // prompts always fit one bucket: never chunked
            image_budget: 4,    // largest encode artifact bucket
            max_decode_batch: 8, // largest decode artifact bucket
        };

        let directory = Arc::new(Mutex::new(SharedDirectory {
            kv: ContentDirectory::new(masks.len()),
            img: ContentDirectory::new(masks.len()),
            peer_pulls: 0,
            stale_pulls: 0,
        }));

        // flight recorder: always on in real mode (the ring is tiny and
        // wall-clock spans are the whole point of the ops surface)
        let registry = Arc::new(Registry::new());
        let tracers: Vec<Arc<Mutex<Tracer>>> = masks
            .iter()
            .map(|_| Arc::new(Mutex::new(Tracer::with_capacity(1 << 14))))
            .collect();

        // supervision (PR 9): per-instance heartbeat stamps + death flags
        let supervisor = SupervisorConfig::default();
        let heartbeats: Vec<Arc<AtomicU64>> =
            masks.iter().map(|_| Arc::new(AtomicU64::new(0))).collect();
        let dead: Vec<Arc<AtomicBool>> =
            masks.iter().map(|_| Arc::new(AtomicBool::new(false))).collect();

        let mut joins = Vec::new();
        for (idx, rx) in receivers.into_iter().enumerate() {
            let mask = masks[idx];
            let peers: Vec<(Sender<Msg>, StageMask)> = senders
                .iter()
                .cloned()
                .zip(masks.iter().copied())
                .collect();
            let planes = 2 * cfg.layers;
            let mut kv =
                PagedCache::new(cfg.pool_blocks, cfg.block_size, cfg.max_blocks_per_seq);
            kv.set_eviction_tracking(true);
            let mut img = PagedCache::new(64, cfg.img_tokens, 4).with_cost_class(COST_IMAGE);
            img.set_eviction_tracking(true);
            let inst = RealInstance {
                idx,
                mask,
                device: device.clone(),
                peers,
                results: results_tx.clone(),
                epoch,
                policy,
                sched: policy.make(mask),
                drain_to: None,
                peer_draining: vec![false; masks.len()],
                ctrl: ctrl_tx.clone(),
                last_sample: 0.0,
                budgets,
                queues: Queues::default(),
                kv,
                kv_store: CacheStore::new(planes, cfg.pool_blocks, cfg.block_size, cfg.hidden),
                img,
                img_store: CacheStore::new(1, 64, cfg.img_tokens, cfg.hidden),
                data: FxHashMap::default(),
                inbound: Vec::new(),
                pending_in: FxHashMap::default(),
                dir_kv: ContentDirectory::new(masks.len()),
                dir_img: ContentDirectory::new(masks.len()),
                shared_dir: Arc::clone(&directory),
                fetch_parked: FxHashMap::default(),
                router: Router::new(RoutePolicy::RoundRobin, idx as u64),
                tokenizer: Tokenizer::new(),
                scratch_slots: Vec::new(),
                heartbeat: Arc::clone(&heartbeats[idx]),
                retry: supervisor.retry,
                failed_steps: 0,
                obs: InstanceObs::new(&registry, idx, Arc::clone(&tracers[idx])),
            };
            joins.push(
                std::thread::Builder::new()
                    .name(format!("hydra-inst-{idx}"))
                    .spawn(move || inst.run(rx))
                    .expect("spawn instance"),
            );
        }

        drop(ctrl_tx); // controller rx must disconnect when instances exit

        let ctrl_join = match (controller, ctrl_rx, control.clone()) {
            (Some(cc), Some(rx), Some(shared)) => Some(spawn_controller_thread(
                cc,
                rx,
                shared,
                senders.clone(),
                dead.clone(),
                epoch,
                Arc::clone(&ctrl_stop),
            )),
            _ => None,
        };

        let sup_stop = Arc::new(AtomicBool::new(false));
        let up: Vec<Arc<Gauge>> = (0..masks.len())
            .map(|i| {
                let g = registry.gauge(&format!("hydra_instance_up{{instance=\"{i}\"}}"));
                g.set(1.0);
                g
            })
            .collect();
        let sup_join = Some(spawn_supervisor_thread(
            supervisor,
            epoch,
            heartbeats,
            dead.clone(),
            up,
            registry.counter("hydra_instance_deaths_total"),
            Arc::clone(&sup_stop),
        ));

        Ok(RealCluster {
            senders,
            masks,
            results_rx: Some(results_rx),
            device,
            joins,
            device_join: Some(device_join),
            router: Router::new(RoutePolicy::RoundRobin, 7),
            tokenizer: Tokenizer::new(),
            epoch,
            next_id: 0,
            directory,
            affinity_streak: FxHashMap::default(),
            control,
            ctrl_stop,
            ctrl_join,
            dead,
            supervisor,
            sup_stop,
            sup_join,
            results_tx,
            inflight: FxHashMap::default(),
            retries: registry.counter("hydra_submit_retries_total"),
            redispatches: registry.counter("hydra_redispatches_total"),
            duplicates: registry.counter("hydra_duplicate_results_total"),
            dead_letters: registry.counter("hydra_dead_letters_total"),
            submitted: registry.counter("hydra_requests_total"),
            rejected: registry.counter("hydra_requests_rejected_total"),
            registry,
            tracers,
        })
    }

    pub fn cfg(&self) -> &crate::runtime::VlmConfig {
        self.device.cfg()
    }

    /// The id the next `submit` will assign (the API server registers its
    /// result waiter before submitting to avoid a race).
    pub fn peek_next_id(&self) -> u64 {
        self.next_id
    }

    /// Preprocess (tokenize + image) and dispatch a request. Returns its id.
    pub fn submit(
        &mut self,
        prompt: &str,
        image: Option<&Image>,
        sampling: SamplingParams,
    ) -> Result<RequestId> {
        let cfg = *self.device.cfg();
        self.submitted.inc();
        let tokens = self.tokenizer.apply_chat_template(prompt, image.is_some());
        let max_txt = if image.is_some() {
            // largest mm bucket minus image tokens
            80 - cfg.img_tokens
        } else {
            64
        };
        if tokens.len() > max_txt {
            self.rejected.inc();
            anyhow::bail!("prompt too long: {} tokens > {max_txt}", tokens.len());
        }
        let pixels = image.map(|img| img.preprocess(cfg.img_size));
        // content identity: the pixel hash keys image-embedding reuse
        let image_hash = pixels.as_ref().map(|p| content::hash_f32s(p));
        let prefill = tokens.len() + if image.is_some() { cfg.img_tokens } else { 0 };
        let max_out = cfg.max_context().saturating_sub(prefill + 1);
        let mut sampling = sampling;
        sampling.max_tokens = sampling.max_tokens.clamp(1, max_out);

        let id = RequestId(self.next_id);
        self.next_id += 1;
        let spec = RequestSpec {
            id,
            arrival: self.epoch.elapsed().as_secs_f64(),
            num_images: usize::from(image.is_some()),
            tokens_per_image: cfg.img_tokens,
            prompt_tokens: tokens.len(),
            output_tokens: sampling.max_tokens,
            image_hash,
            ..Default::default()
        };
        let first = spec.first_stage();
        // live layout: under the elastic controller, masks change and
        // draining instances must not receive new work
        let (masks, draining) = match &self.control {
            Some(c) => {
                let s = c.lock().unwrap();
                (s.masks.clone(), s.draining.clone())
            }
            None => (self.masks.clone(), vec![false; self.masks.len()]),
        };
        // dead instances (supervisor-flagged) never receive new work;
        // `pick_peer_affinity` falls back to draining peers when no one
        // else serves the stage, so the dead must be excluded outright
        let candidates: Vec<usize> = (0..masks.len())
            .filter(|&i| masks[i].serves(first) && !self.dead[i].load(Ordering::Relaxed))
            .collect();
        // cache affinity from the content directory: score every candidate
        // by the tokens of this request's content its cache actually
        // holds (image-embedding blocks + leading KV-prefix blocks) — the
        // gossip-fed, block-level replacement for the old last-instance
        // guess.
        let img_hashes = match image_hash {
            Some(h) => content::image_block_hashes(h, 1),
            None => Vec::new(),
        };
        // only the chain's HEAD block — holding it is a reliable proxy
        // for holding the prefix, and hashing the whole prompt here would
        // duplicate the full chain the instance computes anyway
        let img_head = spec.image_tokens().min(cfg.block_size);
        let txt_head = tokens.len().min(cfg.block_size.saturating_sub(img_head));
        let kv_head =
            content::token_kv_hashes(&tokens[..txt_head], image_hash, img_head, cfg.block_size);
        let affinity: Vec<f64> = {
            let mut d = self.directory.lock().unwrap();
            let img_pfx = d.img.prefix_blocks(&img_hashes);
            let kv_pfx = d.kv.prefix_blocks(&kv_head);
            candidates
                .iter()
                .map(|&i| {
                    (img_pfx[i] * cfg.img_tokens + kv_pfx[i] * cfg.block_size) as f64
                })
                .collect()
        };
        // Consecutive submits allowed to ride one key's affinity before a
        // forced re-balance (the cluster router sees no queue depths):
        // the spread instance warms via peer-pull and the directory then
        // offers two holders.
        const AFFINITY_STREAK: u32 = 8;
        let content_key = image_hash.or_else(|| kv_head.first().copied());
        let streak = content_key
            .and_then(|k| self.affinity_streak.get(&k).copied())
            .unwrap_or(0);
        let affinity: Vec<f64> = if streak >= AFFINITY_STREAK {
            vec![0.0; candidates.len()] // forced re-balance round
        } else {
            affinity
        };
        let Some(target) = pick_peer_affinity(&mut self.router, &candidates, &draining, &affinity)
        else {
            self.rejected.inc();
            anyhow::bail!("no instance serves {first:?}");
        };
        // the streak advances only when the CHOSEN target actually rode
        // affinity — a submit routed away from a (e.g. draining) holder
        // is already spread and must not burn re-balance rounds
        let target_pos = candidates
            .iter()
            .position(|&c| c == target)
            .expect("target comes from candidates");
        let rode_affinity = affinity[target_pos] > 0.0;
        if let Some(k) = content_key {
            if self.affinity_streak.len() > 4096 {
                self.affinity_streak.clear(); // bounded memory
            }
            let next = if rode_affinity && streak < AFFINITY_STREAK { streak + 1 } else { 0 };
            self.affinity_streak.insert(k, next);
        }
        // bounded-retry dispatch: a closed mailbox means the worker is
        // gone — mark it dead (so routing and the supervisor agree), back
        // off, and retry on a surviving candidate instead of rejecting
        let prepared = PreparedRequest { spec, tokens, pixels, sampling, epoch: 0 };
        let mut target = target;
        let mut attempt = 0usize;
        loop {
            if self.senders[target].send(Msg::Submit(Box::new(prepared.clone()))).is_ok() {
                break;
            }
            self.dead[target].store(true, Ordering::Relaxed);
            attempt += 1;
            if attempt >= self.supervisor.retry.max_attempts {
                self.rejected.inc();
                anyhow::bail!("instance {target} is down (gave up after {attempt} attempts)");
            }
            self.retries.inc();
            std::thread::sleep(Duration::from_millis(
                self.supervisor.retry.delay_ms(attempt - 1),
            ));
            let live: Vec<usize> = candidates
                .iter()
                .copied()
                .filter(|&i| !self.dead[i].load(Ordering::Relaxed))
                .collect();
            match pick_peer(&mut self.router, &live, &draining) {
                Some(t) => target = t,
                None => {
                    self.rejected.inc();
                    anyhow::bail!("no live instance serves {first:?}");
                }
            }
        }
        // track the dispatch for re-dispatch/dead-letter on target death
        // (collect-mode only: API mode takes the receiver and relies on
        // the instance-side dead-letter path)
        if self.results_rx.is_some() {
            self.inflight
                .insert(id.0, Inflight { prepared, target, epoch: 0, dead_lettered: false });
        }
        Ok(id)
    }

    /// Move work stranded on dead instances: each in-flight request whose
    /// target the supervisor marked dead is re-dispatched (bumped epoch)
    /// to a live instance serving its first stage, or dead-lettered with
    /// a structured error when none exists / the retry budget is spent.
    /// Duplicate finishes from a merely-stalled "dead" instance are
    /// handled by `collect`'s first-result-wins accounting.
    fn redispatch_dead(&mut self) {
        let n = self.senders.len();
        let dead_now: Vec<bool> =
            (0..n).map(|i| self.dead[i].load(Ordering::Relaxed)).collect();
        if !dead_now.iter().any(|&d| d) {
            return;
        }
        let ids: Vec<u64> = self
            .inflight
            .iter()
            .filter(|(_, f)| !f.dead_lettered && dead_now[f.target])
            .map(|(id, _)| *id)
            .collect();
        if ids.is_empty() {
            return;
        }
        let (masks, draining) = match &self.control {
            Some(c) => {
                let s = c.lock().unwrap();
                (s.masks.clone(), s.draining.clone())
            }
            None => (self.masks.clone(), vec![false; n]),
        };
        for id in ids {
            let Some(mut f) = self.inflight.remove(&id) else { continue };
            let first = f.prepared.spec.first_stage();
            let from = f.target;
            f.epoch += 1;
            f.prepared.epoch = f.epoch;
            let live: Vec<usize> = (0..n)
                .filter(|&i| masks[i].serves(first) && !dead_now[i])
                .collect();
            let mut sent = false;
            if (f.epoch as usize) <= self.supervisor.retry.max_attempts {
                if let Some(t) = pick_peer(&mut self.router, &live, &draining) {
                    if self.senders[t].send(Msg::Submit(Box::new(f.prepared.clone()))).is_ok()
                    {
                        f.target = t;
                        self.redispatches.inc();
                        sent = true;
                    }
                }
            }
            if !sent {
                f.dead_lettered = true;
                self.dead_letters.inc();
                let _ = self.results_tx.send(ServeResult {
                    id: RequestId(id),
                    tokens: Vec::new(),
                    text: String::new(),
                    lifecycle: Lifecycle::new(f.prepared.spec.arrival),
                    error: Some(format!(
                        "instance {from} died; no live replacement serves {first:?}"
                    )),
                });
            }
            self.inflight.insert(id, f);
        }
    }

    /// Collect `n` results (blocking, with an overall timeout). On
    /// timeout the results that did arrive come back inside
    /// [`CollectError::Timeout`] instead of being dropped (and instead of
    /// the panic this used to be). Between receives, work stranded on
    /// instances the supervisor marked dead is re-dispatched; duplicate
    /// finishes from superseded dispatches are dropped (exactly-once per
    /// request id).
    pub fn collect(
        &mut self,
        n: usize,
        timeout: Duration,
    ) -> std::result::Result<Vec<ServeResult>, CollectError> {
        if self.results_rx.is_none() {
            return Err(CollectError::ReceiverTaken);
        }
        let deadline = Instant::now() + timeout;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            self.redispatch_dead();
            let now = Instant::now();
            if now >= deadline {
                return Err(CollectError::Timeout { partial: out, expected: n });
            }
            // short receive slices so redispatch keeps running while idle
            let step = (deadline - now).min(Duration::from_millis(50));
            let rx = self.results_rx.as_ref().expect("checked above");
            match rx.recv_timeout(step) {
                Ok(r) => {
                    if self.inflight.remove(&r.id.0).is_some() {
                        out.push(r);
                    } else {
                        // late duplicate from a superseded dispatch epoch
                        // (or a merely-stalled instance finishing a
                        // request that was already dead-lettered)
                        self.duplicates.inc();
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(CollectError::Timeout { partial: out, expected: n })
                }
            }
        }
        Ok(out)
    }

    /// Move the results receiver out (for a dispatcher thread, e.g. the
    /// HTTP API). After this, `collect` must not be used.
    pub fn take_results(&mut self) -> Option<Receiver<ServeResult>> {
        self.results_rx.take()
    }

    /// Live layout + controller state (the `/status` endpoint's body).
    pub fn status(&self) -> Json {
        let (masks, draining, reconfigs, elastic) = match &self.control {
            Some(c) => {
                let s = c.lock().unwrap();
                (s.masks.clone(), s.draining.clone(), s.reconfigs, true)
            }
            None => (self.masks.clone(), vec![false; self.masks.len()], 0, false),
        };
        let instances: Vec<Json> = masks
            .iter()
            .zip(&draining)
            .enumerate()
            .map(|(i, (m, d))| {
                Json::obj(vec![
                    ("idx", Json::num(i as f64)),
                    ("stages", Json::str(m.label())),
                    ("draining", Json::Bool(*d)),
                    ("dead", Json::Bool(self.dead[i].load(Ordering::Relaxed))),
                ])
            })
            .collect();
        let label = masks.iter().map(|m| m.label()).collect::<Vec<_>>().join("+");
        let dir = {
            let d = self.directory.lock().unwrap();
            Json::obj(vec![
                ("kv_entries", Json::num(d.kv.len() as f64)),
                ("img_entries", Json::num(d.img.len() as f64)),
                (
                    "publishes",
                    Json::num((d.kv.stats().publishes + d.img.stats().publishes) as f64),
                ),
                (
                    "retractions",
                    Json::num((d.kv.stats().retractions + d.img.stats().retractions) as f64),
                ),
                ("peer_pulls", Json::num(d.peer_pulls as f64)),
                ("stale_pulls", Json::num(d.stale_pulls as f64)),
            ])
        };
        Json::obj(vec![
            ("cluster", Json::str(label)),
            ("elastic", Json::Bool(elastic)),
            ("reconfigs", Json::num(reconfigs as f64)),
            ("directory", dir),
            ("instances", Json::arr(instances)),
            ("metrics", self.registry.snapshot_json()),
        ])
    }

    /// Prometheus text exposition (the `/metrics` scrape body): the live
    /// registry — TTFT/TPOT histograms, per-stage queue-depth gauges,
    /// admission/finish/migration counters — plus directory and
    /// reconfiguration state sampled at scrape time.
    pub fn metrics_text(&self) -> String {
        use std::fmt::Write as _;
        let mut out = self.registry.render_prometheus();
        let (kv_entries, img_entries, publishes, retractions, peer_pulls, stale_pulls) = {
            let d = self.directory.lock().unwrap();
            (
                d.kv.len(),
                d.img.len(),
                d.kv.stats().publishes + d.img.stats().publishes,
                d.kv.stats().retractions + d.img.stats().retractions,
                d.peer_pulls,
                d.stale_pulls,
            )
        };
        let reconfigs = self.control.as_ref().map_or(0, |c| c.lock().unwrap().reconfigs);
        let _ = write!(
            out,
            "# TYPE hydra_directory_entries gauge\n\
             hydra_directory_entries{{plane=\"kv\"}} {kv_entries}\n\
             hydra_directory_entries{{plane=\"img\"}} {img_entries}\n\
             # TYPE hydra_directory_publishes_total counter\n\
             hydra_directory_publishes_total {publishes}\n\
             # TYPE hydra_directory_retractions_total counter\n\
             hydra_directory_retractions_total {retractions}\n\
             # TYPE hydra_peer_pulls_total counter\n\
             hydra_peer_pulls_total {peer_pulls}\n\
             # TYPE hydra_stale_pulls_total counter\n\
             hydra_stale_pulls_total {stale_pulls}\n\
             # TYPE hydra_reconfigs_total counter\n\
             hydra_reconfigs_total {reconfigs}\n"
        );
        out
    }

    /// Flight-recorder snapshot as Chrome trace-event JSON (the `/trace`
    /// endpoint's body — open it in Perfetto / `chrome://tracing`). Merges
    /// every instance's ring, oldest-first by wall-clock start.
    pub fn trace_json(&self) -> Json {
        let mut spans: Vec<Span> = Vec::new();
        for t in &self.tracers {
            spans.extend(t.lock().unwrap().snapshot());
        }
        spans.sort_by(|a, b| a.start.total_cmp(&b.start));
        chrome_trace_json(&spans)
    }

    /// Graceful shutdown: stop the supervisor (instances going away on
    /// purpose must not be scored as deaths), then instances, the
    /// controller, and the device.
    pub fn shutdown(mut self) {
        self.sup_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.sup_join.take() {
            let _ = j.join();
        }
        for tx in &self.senders {
            let _ = tx.send(Msg::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
        self.ctrl_stop.store(true, Ordering::Relaxed);
        if let Some(j) = self.ctrl_join.take() {
            let _ = j.join();
        }
        self.device.shutdown();
        if let Some(j) = self.device_join.take() {
            let _ = j.join();
        }
    }
}

/// The elastic controller thread: folds instance samples into the
/// estimator, runs the flip policy once per tick, and finalizes flips
/// (peer-table updates + shared layout state) when instances report done.
fn spawn_controller_thread(
    cc: ControllerConfig,
    rx: Receiver<ControlEvent>,
    shared: Arc<Mutex<ControlShared>>,
    senders: Vec<Sender<Msg>>,
    dead: Vec<Arc<AtomicBool>>,
    epoch: Instant,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("hydra-controller".into())
        .spawn(move || {
            let n = senders.len();
            let mut est =
                StageLoadEstimator::new(cc.clone(), StageRates::default_real(), None);
            let mut pol = ReconfigPolicy::new(cc.clone());
            let mut tracker = DrainTracker::new(n);
            let mut latest: Vec<Option<InstanceSample>> = vec![None; n];
            // finished-request lifecycles inside the estimator window:
            // the real-mode source of the TTFT/TPOT tails
            let mut recent: std::collections::VecDeque<Lifecycle> =
                std::collections::VecDeque::new();
            let mut last_tick = 0.0f64;
            let poll = Duration::from_millis(((cc.tick * 500.0) as u64).max(1));
            let broadcast_drain = |senders: &[Sender<Msg>], idx: usize, draining: bool| {
                for tx in senders {
                    let _ = tx.send(Msg::PeerDrain { idx, draining });
                }
            };
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                match rx.recv_timeout(poll) {
                    Ok(ControlEvent::Sample { idx, sample }) => {
                        if idx < n {
                            latest[idx] = Some(sample);
                        }
                    }
                    Ok(ControlEvent::Finished(lc)) => recent.push_back(*lc),
                    Ok(ControlEvent::FlipDone { idx, mask }) => {
                        let now = epoch.elapsed().as_secs_f64();
                        let from = {
                            let mut s = shared.lock().unwrap();
                            let from = s.masks[idx];
                            s.masks[idx] = mask;
                            s.draining[idx] = false;
                            s.reconfigs += 1;
                            from
                        };
                        // may race with a just-sent CancelDrain; the flip won
                        if tracker.is_draining(idx) {
                            tracker.complete(now, idx, from);
                        }
                        for tx in &senders {
                            let _ = tx.send(Msg::PeerMask { idx, mask });
                        }
                        broadcast_drain(&senders, idx, false);
                    }
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
                let now = epoch.elapsed().as_secs_f64();
                if now - last_tick < cc.tick {
                    continue;
                }
                last_tick = now;
                // give up on drains that never empty (sustained inflow):
                // the instance keeps its current role and rejoins routing
                for i in 0..n {
                    if tracker.is_draining(i) && tracker.expired(now, i, cc.drain_timeout) {
                        tracker.cancel(i);
                        shared.lock().unwrap().draining[i] = false;
                        let _ = senders[i].send(Msg::CancelDrain);
                        broadcast_drain(&senders, i, false);
                    }
                }
                let (masks, draining) = {
                    let s = shared.lock().unwrap();
                    (s.masks.clone(), s.draining.clone())
                };
                // supervisor-flagged dead instances are unavailable
                // exactly like draining ones: their backlog still counts
                // as demand, their capacity does not, and the policy
                // neither picks them as donor nor counts them as stage
                // coverage — the layout re-plans around the hole
                let unavailable: Vec<bool> = (0..n)
                    .map(|i| draining[i] || dead[i].load(Ordering::Relaxed))
                    .collect();
                let insts: Vec<InstanceSample> = (0..n)
                    .map(|i| {
                        let mut s = latest[i]
                            .clone()
                            .unwrap_or_else(|| InstanceSample::idle(masks[i], draining[i]));
                        s.draining = unavailable[i];
                        s
                    })
                    .collect();
                // windowed latency tails from finished requests (tee'd via
                // ControlEvent::Finished), matching the simulator's
                // estimator input
                let cutoff = now - cc.window;
                while recent
                    .front()
                    .is_some_and(|lc| lc.finished_at.unwrap_or(0.0) < cutoff)
                {
                    recent.pop_front();
                }
                let w = crate::metrics::window_stats(recent.iter(), cutoff);
                est.observe(ClusterSample {
                    t: now,
                    instances: insts,
                    ttft_p90: w.ttft_p90(),
                    tpot_p90: w.tpot_p90(),
                });
                let Some(load) = est.snapshot() else { continue };
                if let Some(d) = pol.decide(now, &load, &masks, &unavailable) {
                    if tracker.begin(now, d.instance, d.to) {
                        shared.lock().unwrap().draining[d.instance] = true;
                        let _ = senders[d.instance].send(Msg::Reconfigure(d.to));
                        broadcast_drain(&senders, d.instance, true);
                    }
                }
            }
        })
        .expect("spawn controller")
}

/// The supervisor thread (PR 9): scans per-instance heartbeat stamps
/// every `heartbeat_interval` and flips the shared death flags — an
/// instance silent for longer than `dead_after` is marked dead (its
/// `hydra_instance_up` gauge drops to 0 and `hydra_instance_deaths_total`
/// counts it); a flagged instance that beats again is resurrected (it was
/// stalled, not gone — the epoch/dedup machinery makes the false positive
/// safe). Routing and `collect`-side re-dispatch consume the flags.
fn spawn_supervisor_thread(
    cfg: SupervisorConfig,
    epoch: Instant,
    heartbeats: Vec<Arc<AtomicU64>>,
    dead: Vec<Arc<AtomicBool>>,
    up: Vec<Arc<Gauge>>,
    deaths: Arc<Counter>,
    stop: Arc<AtomicBool>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("hydra-supervisor".into())
        .spawn(move || {
            let deadline_ms = cfg.dead_after_ms();
            loop {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let now_ms = epoch.elapsed().as_millis() as u64;
                for i in 0..heartbeats.len() {
                    let hb = heartbeats[i].load(Ordering::Relaxed);
                    let alive = now_ms.saturating_sub(hb) <= deadline_ms;
                    let was_dead = dead[i].load(Ordering::Relaxed);
                    if !alive && !was_dead {
                        dead[i].store(true, Ordering::Relaxed);
                        up[i].set(0.0);
                        deaths.inc();
                        crate::util::logging::log(
                            crate::util::logging::Level::Warn,
                            "instance",
                            format_args!(
                                "supervisor: instance {i} silent for >{:.1}s, marked dead",
                                cfg.dead_after
                            ),
                        );
                    } else if alive && was_dead {
                        dead[i].store(false, Ordering::Relaxed);
                        up[i].set(1.0);
                        crate::util::logging::log(
                            crate::util::logging::Level::Info,
                            "instance",
                            format_args!("supervisor: instance {i} heartbeat resumed"),
                        );
                    }
                }
                std::thread::sleep(cfg.scan_period());
            }
        })
        .expect("spawn supervisor")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy(id: u64) -> ServeResult {
        ServeResult {
            id: RequestId(id),
            tokens: vec![1, 2],
            text: "ok".into(),
            lifecycle: Lifecycle::new(0.0),
            error: None,
        }
    }

    #[test]
    fn collect_times_out_with_partial_results_instead_of_panicking() {
        let (tx, rx) = channel();
        tx.send(dummy(0)).unwrap();
        tx.send(dummy(1)).unwrap();
        let err = collect_results(&rx, 3, Duration::from_millis(30)).unwrap_err();
        match err {
            CollectError::Timeout { partial, expected } => {
                assert_eq!(expected, 3);
                assert_eq!(partial.len(), 2);
                assert_eq!(partial[0].id, RequestId(0));
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn collect_returns_ok_when_everything_arrives() {
        let (tx, rx) = channel();
        for i in 0..3 {
            tx.send(dummy(i)).unwrap();
        }
        let out = collect_results(&rx, 3, Duration::from_secs(5)).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn sender_hangup_yields_partial_timeout_not_a_panic() {
        let (tx, rx) = channel();
        tx.send(dummy(7)).unwrap();
        drop(tx);
        let err = collect_results(&rx, 2, Duration::from_secs(5)).unwrap_err();
        match err {
            CollectError::Timeout { partial, expected: 2 } => assert_eq!(partial.len(), 1),
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn collect_error_display_is_structured() {
        let e = CollectError::Timeout { partial: vec![dummy(0)], expected: 4 };
        assert_eq!(e.to_string(), "collect timed out with 1/4 results");
        assert!(CollectError::ReceiverTaken.to_string().contains("taken"));
    }
}
